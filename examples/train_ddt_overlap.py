"""End-to-end driver: LM training fed through the sPIN packet pipeline
(paper §V-C as a framework feature).

    PYTHONPATH=src python examples/train_ddt_overlap.py            # quick
    PYTHONPATH=src python examples/train_ddt_overlap.py --full     # ~100M

Every training batch arrives as SLMP segments whose payload is a
DDT-packed (strided, non-contiguous) buffer; the device-side SpinIngest
(match → reassemble → committed-DDT unpack) is double-buffered against
the train step, and the run reports the paper's overlap ratio
R = T_train / (T_train + T_poll) next to the loss curve.  Checkpoints are
atomic; a simulated preemption (--crash) exercises the restart path.
"""
import sys
sys.path.insert(0, "src")

import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M-param model, 200 steps (slow on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.full:
        # ~100M params: qwen3 family at width 512 / 8 layers
        from repro import configs as cfglib
        from repro.configs.base import ModelConfig
        import repro.configs.qwen3_1_7b as q3

        def smoke_100m():
            return ModelConfig(
                name="qwen3-100m", family="dense",
                n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                head_dim=64, d_ff=1536, vocab=32000,
                qk_norm=True, mlp_kind="swiglu", remat="none")

        q3_orig = q3.smoke
        q3.smoke = smoke_100m
        try:
            result = train_cli.main([
                "--arch", "qwen3-1.7b", "--smoke", "--spin-ingest",
                "--steps", str(args.steps or 200), "--batch", "8",
                "--seq", "128", "--lr", "1e-3",
                "--ckpt-dir", "/tmp/repro-100m-ckpt"])
        finally:
            q3.smoke = q3_orig
    else:
        result = train_cli.main([
            "--arch", "qwen3-1.7b", "--smoke", "--spin-ingest",
            "--steps", str(args.steps or 60), "--batch", "8",
            "--seq", "64", "--lr", "3e-3",
            "--ckpt-dir", "/tmp/repro-quick-ckpt"])

    hist = result["history"]
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print(f"train_ddt_overlap OK: loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f}, overlap R={result['overlap_ratio']:.4f}")


if __name__ == "__main__":
    main()
