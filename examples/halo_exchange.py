"""2-D halo exchange over the lossy fabric — MPI vector datatypes with the
column unpack offloaded to each rank's SpinNIC.

    PYTHONPATH=src python examples/halo_exchange.py [H] [W] [loss] [sweeps]

Four ranks tile a periodic 2H×2W grid as a 2×2 process grid.  Each Jacobi
sweep exchanges the halo ring with the four neighbours:

  * row halos are contiguous      → eager SLMP messages;
  * column halos are strided      → ``MPI_Type_vector(H, 1, W+2)``; the
    receive side lands via the NIC DDT-unpack context, which scatters the
    packed column straight into the ghost column of the field array
    (stride and all) by host-memory DMA — no host unpack.

After each exchange every rank relaxes its interior; the distributed
result is checked against a single-domain numpy reference every sweep.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro import mpi
from repro.core import ddt as ddtlib
from repro.net import LinkConfig

TAG_L, TAG_R, TAG_T, TAG_B = 1, 2, 3, 4


def main():
    H = int(sys.argv[1]) if len(sys.argv) > 1 else 32
    W = int(sys.argv[2]) if len(sys.argv) > 2 else 48
    loss = float(sys.argv[3]) if len(sys.argv) > 3 else 0.05
    sweeps = int(sys.argv[4]) if len(sys.argv) > 4 else 3
    PX = PY = 2
    n = PX * PY

    # column datatype: H floats, one per row of the (H+2, W+2) local field
    reg = mpi.DatatypeRegistry()
    col = reg.register(ddtlib.Vector(count=H, blocklen=1, stride=W + 2,
                                     base=ddtlib.MPI_FLOAT), name="column")
    col_bytes = reg.msg_bytes(col)
    comm = mpi.Communicator(
        n, registry=reg, seed=42,
        link_cfg=LinkConfig(loss=loss, latency=2, jitter=2),
        cfg=mpi.MpiConfig(eager_threshold=min(col_bytes, 4096)))
    print(f"2x2 ranks, local {H}x{W} (+halo), loss {loss:.0%}; column "
          f"halo = vector({H},1,{W + 2}) = {col_bytes}B "
          f"{'(NIC-offloaded rendezvous)' if col_bytes >= comm.cfg.eager_threshold else '(eager)'}")

    rng = np.random.default_rng(0)
    fields = [rng.normal(size=(H + 2, W + 2)).astype(np.float32)
              for _ in range(n)]
    G = np.zeros((PY * H, PX * W), np.float32)        # reference domain
    for r in range(n):
        py, px = divmod(r, PX)
        G[py * H:(py + 1) * H, px * W:(px + 1) * W] = fields[r][1:-1, 1:-1]

    def flat_from(r, row, colidx):
        """Contiguous flat view of fields[r] starting at (row, colidx) —
        the strided column lives inside it (vector datatype extent)."""
        return fields[r].reshape(-1)[row * (W + 2) + colidx:]

    def exchange():
        reqs = []
        for r in range(n):
            py, px = divmod(r, PX)
            left = py * PX + (px - 1) % PX
            right = py * PX + (px + 1) % PX
            up = ((py - 1) % PY) * PX + px
            down = ((py + 1) % PY) * PX + px
            # columns: interior edge -> neighbour's ghost (vector datatype)
            reqs.append(comm.irecv(r, flat_from(r, 1, W + 1),
                                   source=right, tag=TAG_L))
            reqs.append(comm.irecv(r, flat_from(r, 1, 0),
                                   source=left, tag=TAG_R))
            reqs.append(comm.isend(r, left, flat_from(r, 1, 1),
                                   tag=TAG_L, datatype=col))
            reqs.append(comm.isend(r, right, flat_from(r, 1, W),
                                   tag=TAG_R, datatype=col))
            # rows: contiguous -> raw eager messages
            reqs.append(comm.irecv(r, fields[r][H + 1, 1:W + 1],
                                   source=down, tag=TAG_T))
            reqs.append(comm.irecv(r, fields[r][0, 1:W + 1],
                                   source=up, tag=TAG_B))
            reqs.append(comm.isend(r, up, fields[r][1, 1:W + 1],
                                   tag=TAG_T))
            reqs.append(comm.isend(r, down, fields[r][H, 1:W + 1],
                                   tag=TAG_B))
        comm.wait_list(reqs, max_ticks=300_000)

    for sweep in range(sweeps):
        t0 = comm.now
        exchange()
        ticks = comm.now - t0
        # verify every exchanged ghost cell against the periodic global
        # reference (corners are not exchanged — a 5-point stencil never
        # reads them)
        for r in range(n):
            py, px = divmod(r, PX)
            rows = np.arange(py * H - 1, (py + 1) * H + 1) % (PY * H)
            cols = np.arange(px * W - 1, (px + 1) * W + 1) % (PX * W)
            want = G[np.ix_(rows, cols)]
            got = fields[r]
            mask = np.ones_like(got, bool)
            mask[0, 0] = mask[0, -1] = mask[-1, 0] = mask[-1, -1] = False
            np.testing.assert_allclose(got[mask], want[mask], rtol=1e-6)
        # Jacobi relaxation on the interior, and on the reference domain
        for r in range(n):
            f = fields[r]
            f[1:-1, 1:-1] = 0.25 * (f[:-2, 1:-1] + f[2:, 1:-1]
                                    + f[1:-1, :-2] + f[1:-1, 2:])
        G = 0.25 * (np.roll(G, 1, 0) + np.roll(G, -1, 0)
                    + np.roll(G, 1, 1) + np.roll(G, -1, 1))
        retx = sum(s["retransmits"] for s in comm.stats())
        print(f"sweep {sweep}: halo exchange ok in {ticks} ticks "
              f"(cumulative retransmits {retx})")
    lost = sum(l["lost"] for l in comm.link_stats())
    print(f"halo_exchange OK — {sweeps} verified sweeps, "
          f"{lost} frames lost on the wire and recovered")


if __name__ == "__main__":
    main()
