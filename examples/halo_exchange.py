"""2-D halo exchange over the lossy fabric — MPI vector datatypes with the
column unpack offloaded to each rank's SpinNIC.

    PYTHONPATH=src python examples/halo_exchange.py [H] [W] [loss] [sweeps]

Four ranks tile a periodic 2H×2W grid as a 2×2 process grid.  Each Jacobi
sweep exchanges the halo ring with the four neighbours:

  * row halos are contiguous      → eager SLMP messages;
  * column halos are strided      → ``MPI_Type_vector(H, 1, W+2)``; the
    receive side lands via the NIC DDT-unpack context, which scatters the
    packed column straight into the ghost column of the field array
    (stride and all) by host-memory DMA — no host unpack.

The sweep demonstrates *real* compute/communication overlap on the
request layer: halos are posted nonblocking, the deep interior (which
needs no ghost cells) relaxes while the fabric progresses under the
modeled compute window, and only the boundary ring waits for the halo
requests — the exposed communication is whatever retransmission tails
poke out of the compute.  The distributed result is checked against a
single-domain numpy reference every sweep.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro import mpi
from repro.core import ddt as ddtlib
from repro.net import LinkConfig

TAG_L, TAG_R, TAG_T, TAG_B = 1, 2, 3, 4


def main():
    H = int(sys.argv[1]) if len(sys.argv) > 1 else 32
    W = int(sys.argv[2]) if len(sys.argv) > 2 else 48
    loss = float(sys.argv[3]) if len(sys.argv) > 3 else 0.05
    sweeps = int(sys.argv[4]) if len(sys.argv) > 4 else 3
    PX = PY = 2
    n = PX * PY

    # column datatype: H floats, one per row of the (H+2, W+2) local field
    reg = mpi.DatatypeRegistry()
    col = reg.register(ddtlib.Vector(count=H, blocklen=1, stride=W + 2,
                                     base=ddtlib.MPI_FLOAT), name="column")
    col_bytes = reg.msg_bytes(col)
    comm = mpi.Communicator(
        n, registry=reg, seed=42,
        link_cfg=LinkConfig(loss=loss, latency=2, jitter=2),
        cfg=mpi.MpiConfig(eager_threshold=min(col_bytes, 4096)))
    print(f"2x2 ranks, local {H}x{W} (+halo), loss {loss:.0%}; column "
          f"halo = vector({H},1,{W + 2}) = {col_bytes}B "
          f"{'(NIC-offloaded rendezvous)' if col_bytes >= comm.cfg.eager_threshold else '(eager)'}")

    rng = np.random.default_rng(0)
    fields = [rng.normal(size=(H + 2, W + 2)).astype(np.float32)
              for _ in range(n)]
    G = np.zeros((PY * H, PX * W), np.float32)        # reference domain
    for r in range(n):
        py, px = divmod(r, PX)
        G[py * H:(py + 1) * H, px * W:(px + 1) * W] = fields[r][1:-1, 1:-1]

    def flat_from(r, row, colidx):
        """Contiguous flat view of fields[r] starting at (row, colidx) —
        the strided column lives inside it (vector datatype extent)."""
        return fields[r].reshape(-1)[row * (W + 2) + colidx:]

    def post_halos():
        reqs = []
        for r in range(n):
            py, px = divmod(r, PX)
            left = py * PX + (px - 1) % PX
            right = py * PX + (px + 1) % PX
            up = ((py - 1) % PY) * PX + px
            down = ((py + 1) % PY) * PX + px
            # columns: interior edge -> neighbour's ghost (vector datatype)
            reqs.append(comm.irecv(r, flat_from(r, 1, W + 1),
                                   source=right, tag=TAG_L))
            reqs.append(comm.irecv(r, flat_from(r, 1, 0),
                                   source=left, tag=TAG_R))
            reqs.append(comm.isend(r, left, flat_from(r, 1, 1),
                                   tag=TAG_L, datatype=col))
            reqs.append(comm.isend(r, right, flat_from(r, 1, W),
                                   tag=TAG_R, datatype=col))
            # rows: contiguous -> raw eager messages
            reqs.append(comm.irecv(r, fields[r][H + 1, 1:W + 1],
                                   source=down, tag=TAG_T))
            reqs.append(comm.irecv(r, fields[r][0, 1:W + 1],
                                   source=up, tag=TAG_B))
            reqs.append(comm.isend(r, up, fields[r][1, 1:W + 1],
                                   tag=TAG_T))
            reqs.append(comm.isend(r, down, fields[r][H, 1:W + 1],
                                   tag=TAG_B))
        return reqs

    def relax_deep(f):
        """Jacobi update of the deep interior (rows/cols 2..H-1/2..W-1):
        reads no ghost cell, so it runs while halos are still in flight."""
        return 0.25 * (f[1:H - 1, 2:W] + f[3:H + 1, 2:W]
                       + f[2:H, 1:W - 1] + f[2:H, 3:W + 1])

    def relax_ring(f):
        """Jacobi update of the boundary ring — the only cells that had to
        wait for the halo exchange."""
        row1 = 0.25 * (f[0, 1:W + 1] + f[2, 1:W + 1]
                       + f[1, 0:W] + f[1, 2:W + 2])
        rowH = 0.25 * (f[H - 1, 1:W + 1] + f[H + 1, 1:W + 1]
                       + f[H, 0:W] + f[H, 2:W + 2])
        col1 = 0.25 * (f[1:H - 1, 1] + f[3:H + 1, 1]
                       + f[2:H, 0] + f[2:H, 2])
        colW = 0.25 * (f[1:H - 1, W] + f[3:H + 1, W]
                       + f[2:H, W - 1] + f[2:H, W + 1])
        return row1, rowH, col1, colW

    COMPUTE_TICKS = 48       # the modeled cost of the deep-interior sweep
    hidden_total = exposed_total = 0
    for sweep in range(sweeps):
        t0 = comm.now
        reqs = post_halos()
        # --- overlap window: deep interior relaxes from OLD values while
        # the fabric makes progress underneath the compute; test() polls
        # without blocking to spot when the exchange finished under it
        deep = [relax_deep(fields[r]) for r in range(n)]
        done_at = None
        for _ in range(COMPUTE_TICKS // 4):
            comm.progress(4)
            if done_at is None and comm.test(*reqs):
                done_at = comm.now - t0
        # --- exposed tail: only the boundary ring still needs the ghosts
        if not comm.test(*reqs):
            comm.wait_list(reqs, max_ticks=300_000)
            done_at = comm.now - t0
        ticks = comm.now - t0
        t_exposed = max(0, done_at - COMPUTE_TICKS)
        hidden_total += done_at - t_exposed
        exposed_total += t_exposed
        # verify every exchanged ghost cell against the periodic global
        # reference (corners are not exchanged — a 5-point stencil never
        # reads them)
        for r in range(n):
            py, px = divmod(r, PX)
            rows = np.arange(py * H - 1, (py + 1) * H + 1) % (PY * H)
            cols = np.arange(px * W - 1, (px + 1) * W + 1) % (PX * W)
            want = G[np.ix_(rows, cols)]
            got = fields[r]
            mask = np.ones_like(got, bool)
            mask[0, 0] = mask[0, -1] = mask[-1, 0] = mask[-1, -1] = False
            np.testing.assert_allclose(got[mask], want[mask], rtol=1e-6)
        # ring update (fresh ghosts + old interior), then commit both
        for r in range(n):
            f = fields[r]
            row1, rowH, col1, colW = relax_ring(f)
            f[2:H, 2:W] = deep[r]
            f[1, 1:W + 1] = row1
            f[H, 1:W + 1] = rowH
            f[2:H, 1] = col1
            f[2:H, W] = colW
        G = 0.25 * (np.roll(G, 1, 0) + np.roll(G, -1, 0)
                    + np.roll(G, 1, 1) + np.roll(G, -1, 1))
        retx = sum(s["retransmits"] for s in comm.stats())
        print(f"sweep {sweep}: halo exchange ok in {ticks} ticks "
              f"({t_exposed} exposed beyond the compute window, "
              f"cumulative retransmits {retx})")
    lost = sum(l["lost"] for l in comm.link_stats())
    R = hidden_total / max(1, hidden_total + exposed_total)
    print(f"halo_exchange OK — {sweeps} verified sweeps, overlap "
          f"R={R:.3f} ({exposed_total} of {hidden_total + exposed_total} "
          f"exchange ticks exposed), "
          f"{lost} frames lost on the wire and recovered")


if __name__ == "__main__":
    main()
