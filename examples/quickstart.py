"""Quickstart: the paper's Listing 1+2 — an offloaded ICMP Echo responder.

    PYTHONPATH=src python examples/quickstart.py

Installs an execution context whose ruleset matches ICMP Echo-Requests
(word-8 / mask 0xff00 / 0x0800, exactly Fig 6), sends pings through the
sNIC, and verifies the replies the packet handler produced — checksum
recomputed on-NIC, MAC/IP swapped, host CPU never touched.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import apps, matching, packet as pkt, spin_nic


def main():
    # fpspin_init(ctx, "/dev/pspin0", handlers, ruleset) equivalent:
    nic = spin_nic.SpinNIC([apps.make_icmp_context()], batch=16)
    state = nic.init_state()

    rs = matching.ruleset_icmp_echo()
    print("ICMP-echo ruleset (paper Listing 2):")
    for r in rs.rules:
        print(f"  idx={r.idx} mask={r.mask:#010x} "
              f"start={r.start:#x} end={r.end:#x}")

    rng = np.random.default_rng(0)
    for seq, size in enumerate([16, 64, 256, 1024]):
        payload = rng.integers(0, 256, size).astype(np.uint8)
        ping = pkt.make_icmp_echo(payload, seq=seq)
        state, egress, to_host = nic.step(
            state, pkt.stack_frames([ping], n=16))
        ev = np.asarray(egress.valid)
        assert ev.sum() == 1, "handler must emit exactly one reply"
        i = int(np.argmax(ev))
        reply = np.asarray(egress.data)[i][:int(np.asarray(egress.length)[i])]
        ck_ok = pkt.internet_checksum_np(reply[pkt.L4_BASE:]) == 0
        echo_ok = bool((reply[pkt.L4_BASE + 8:] == payload).all())
        print(f"ping seq={seq} payload={size:5d}B -> reply "
              f"type={reply[pkt.ICMP_TYPE]} checksum_ok={ck_ok} "
              f"payload_ok={echo_ok}")
        assert ck_ok and echo_ok
    print("quickstart OK: offloaded ICMP responder verified")


if __name__ == "__main__":
    main()
