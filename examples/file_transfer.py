"""Reliable file transfer over SLMP (paper §V-B / Fig 8).

    PYTHONPATH=src python examples/file_transfer.py [size_kb] [window]

Sender segments the file into SLMP packets (SYN on every segment in
window mode); the receiver side runs *entirely in sPIN handlers* on the
sNIC: header handler opens the message context, packet handlers DMA
payloads to host memory at their offsets and ACK, the tail handler pushes
the completion notification into the host FIFO.
"""
import sys
sys.path.insert(0, "src")

import time

import numpy as np

from repro.core import packet as pkt, slmp, spin_nic


def main():
    size_kb = int(sys.argv[1]) if len(sys.argv) > 1 else 256
    window = int(sys.argv[2]) if len(sys.argv) > 2 else 16
    nbytes = size_kb << 10

    nic = spin_nic.SpinNIC([slmp.make_slmp_context()],
                           host_bytes=max(nbytes, 1 << 16), batch=window)
    state = nic.init_state()

    rng = np.random.default_rng(1)
    blob = rng.integers(0, 256, nbytes).astype(np.uint8)
    cfg = slmp.SlmpSenderConfig(window=window)
    frames = slmp.segment_message(blob, msg_id=1001, cfg=cfg)
    print(f"file: {size_kb} KiB -> {len(frames)} SLMP segments, "
          f"window {window}")

    # warm the jit (compile excluded from goodput)
    state, _, _ = nic.step(state, pkt.stack_frames([], n=window))

    t0 = time.perf_counter()
    acked = 0
    for i in range(0, len(frames), window):       # one window per step
        state, egress, _ = nic.step(
            state, pkt.stack_frames(frames[i:i + window], n=window))
        acked += len(slmp.parse_acks(egress))
    dt = time.perf_counter() - t0

    got = nic.read_host(state, 0, nbytes)
    ok = bool((got == blob).all())
    completions = nic.pop_counters(state, slmp.COMPLETION_QUEUE)
    print(f"delivered={ok} acks={acked}/{len(frames)} "
          f"completions={completions.tolist()} "
          f"host-goodput={nbytes / dt / 1e6:.1f} MB/s (this CPU)")
    assert ok and completions.tolist() == [1001]
    print("file_transfer OK")


if __name__ == "__main__":
    main()
