"""Reliable file transfer over SLMP across the two-node fabric (paper
§V-B / Fig 8 — now over an actual lossy wire).

    PYTHONPATH=src python examples/file_transfer.py [size_kb] [window] [loss]

The sender node runs the host-side SLMP state machine (window, timeout,
retransmit); the wire drops/reorders packets per ``loss``; the receiver
side runs *entirely in sPIN handlers* on the peer's sNIC: header handler
opens the message context, packet handlers DMA payloads to host memory at
their offsets and ACK, the tail handler pushes the completion
notification into the host FIFO.
"""
import sys
sys.path.insert(0, "src")

import time

import numpy as np

from repro.core import apps, packet as pkt, slmp
from repro.net import Fabric, LinkConfig, Node, SlmpSenderEngine


def main():
    size_kb = int(sys.argv[1]) if len(sys.argv) > 1 else 256
    window = int(sys.argv[2]) if len(sys.argv) > 2 else 16
    loss = float(sys.argv[3]) if len(sys.argv) > 3 else 0.05
    nbytes = size_kb << 10

    rng = np.random.default_rng(1)
    blob = rng.integers(0, 256, nbytes).astype(np.uint8)
    cfg = slmp.SlmpSenderConfig(window=window, timeout=12,
                                src_mac=pkt.node_mac(0),
                                dst_mac=pkt.node_mac(1))
    sender = SlmpSenderEngine(blob, msg_id=1001, cfg=cfg)
    tx = Node("tx", pkt.node_mac(0), [apps.make_null_context()],
              engines=[sender], batch=max(16, window))
    rx = Node("rx", pkt.node_mac(1), [slmp.make_slmp_context()],
              host_bytes=max(nbytes, 1 << 16), batch=max(16, window))
    fab = Fabric([tx, rx], link_cfg=LinkConfig(loss=loss, latency=2,
                                               jitter=2), seed=2)
    print(f"file: {size_kb} KiB -> {sender.sender.nseg} SLMP segments, "
          f"window {window}, loss {loss:.0%}")

    # first tick compiles both NIC datapaths + the link model; time the rest
    fab.tick()
    t0 = time.perf_counter()
    ticks = 1 + fab.run(max_ticks=200_000)
    dt = time.perf_counter() - t0

    got = rx.read_host(0, nbytes)
    ok = bool((got == blob).all())
    s = sender.sender
    lost = sum(l["lost"] for l in fab.link_stats())
    print(f"delivered={ok} ticks={ticks} "
          f"sent={s.sent_frames} retransmits={s.retransmits} "
          f"completions={rx.completions} "
          f"link={fab.link_stats()[1]} "
          f"host-goodput={nbytes / dt / 1e6:.1f} MB/s (this CPU)")
    assert ok and 1001 in rx.completions and s.done
    if lost > 0:
        assert s.retransmits > 0, "drops occurred but no retransmission"
    print("file_transfer OK")


if __name__ == "__main__":
    main()
