"""Serving example: prefill + batched greedy decode on a smoke config.

    PYTHONPATH=src python examples/serve_decode.py [arch]

Runs the same prefill/serve_step programs the multi-pod dry-run lowers
for the decode_32k / long_500k cells (there with 256/512-chip shardings).
"""
import sys
sys.path.insert(0, "src")

from repro.launch import serve as serve_cli


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-780m"
    result = serve_cli.main(["--arch", arch, "--smoke", "--batch", "2",
                             "--prompt-len", "24", "--gen", "8"])
    assert result["tokens"].shape == (2, 8)
    print("serve_decode OK")


if __name__ == "__main__":
    main()
