"""repro.net — the multi-node network fabric.

Connects several :class:`~repro.core.spin_nic.SpinNIC` instances over
simulated links with configurable loss, reordering, duplication and
latency, so every handler application becomes a multi-node experiment
(the paper's full-system evaluation: real endpoints, a real wire).

  link.py    jittable LinkModel — a pure function of (PRNG key, LinkState)
  node.py    Node = SpinNIC + host-side protocol engines (SLMP sender,
             ping-pong client)
  fabric.py  Fabric = N nodes + N ingress links + MAC routing + tick()
"""
from repro.net.fabric import Fabric
from repro.net.link import Link, LinkConfig, LinkState
from repro.net.node import Node, PingPongClient, SlmpSenderEngine

__all__ = ["Fabric", "Link", "LinkConfig", "LinkState", "Node",
           "PingPongClient", "SlmpSenderEngine"]
