"""A fabric node: one SpinNIC plus the host software beside it.

The paper's end-to-end experiments always pair the sNIC with host-side
protocol code — the SLMP sender that segments, windows and retransmits,
and the ping-pong client that stamps RTTs.  A :class:`Node` bundles a
:class:`~repro.core.spin_nic.SpinNIC` (+ its ``NICState``) with a list of
*host engines* that generate and consume traffic from inside the
simulation:

  * handler egress (ACKs, echo replies) leaves through the node's wire;
  * frames the matcher does not claim are forwarded ``to_host`` — exactly
    the Corundum/host datapath — and the engines consume them there
    (ACKs land at the SLMP sender, pongs at the ping-pong client);
  * completion notifications (counter queue 0) are drained every tick.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import handlers as H
from repro.core import packet as pkt
from repro.core import slmp
from repro.core import spin_nic


class HostEngine:
    """Host-side traffic generator/consumer stepped by the fabric tick."""

    def poll(self, now: int) -> List[np.ndarray]:
        """Frames this engine puts on the wire at tick ``now``."""
        return []

    def on_host_frames(self, frames: List[np.ndarray], now: int) -> None:
        """Frames forwarded to the host datapath (non-matching ingress)."""

    def on_completions(self, values: np.ndarray, now: int) -> None:
        """Values drained from the completion counter FIFO."""

    @property
    def done(self) -> bool:
        return True

    def snapshot(self) -> dict:
        return {}

    def restore(self, snap: dict) -> None:
        pass


class SlmpSenderEngine(HostEngine):
    """Host half of a reliable SLMP transfer (wraps core.slmp.SlmpSender)."""

    def __init__(self, msg: np.ndarray, msg_id: int,
                 cfg: Optional[slmp.SlmpSenderConfig] = None):
        self.sender = slmp.SlmpSender(msg, msg_id, cfg)

    def poll(self, now: int) -> List[np.ndarray]:
        return self.sender.poll(now)

    def on_host_frames(self, frames: List[np.ndarray], now: int) -> None:
        for msg_id, off in slmp.parse_acks(pkt.stack_frames(frames)) \
                if frames else []:
            self.sender.on_ack(msg_id, off)

    @property
    def done(self) -> bool:
        # "done" = generates no more traffic: delivered OR gave up
        return self.sender.done or self.sender.failed

    @property
    def failed(self) -> bool:
        return self.sender.failed

    def snapshot(self) -> dict:
        return self.sender.snapshot()

    def restore(self, snap: dict) -> None:
        self.sender.restore(snap)


class PingPongClient(HostEngine):
    """Fires ``count`` pings at a peer, one outstanding, recording the RTT
    of each pong in fabric ticks (the Fig-7 client, ICMP or UDP)."""

    def __init__(self, count: int, payload: int = 56, proto: str = "udp",
                 dport: int = 9999, src_mac: Optional[bytes] = None,
                 dst_mac: Optional[bytes] = None, timeout: int = 64):
        assert proto in ("icmp", "udp")
        assert payload >= 2, "seq stamp needs two payload bytes"
        self.count = count
        self.proto = proto
        self.dport = dport
        self.src_mac = src_mac
        self.dst_mac = dst_mac
        self.timeout = timeout
        self.payload = np.arange(payload, dtype=np.uint8)
        self.seq = 0
        self.sent_at = -1          # -1: nothing outstanding
        self.first_sent = -1       # first transmission of the current seq
        self.rtts: List[int] = []
        self.timeouts = 0

    def _frame(self, seq: int) -> np.ndarray:
        # the responder echoes the payload verbatim, so a seq stamped into
        # the first two payload bytes identifies which ping a pong answers
        payload = self.payload.copy()
        payload[0], payload[1] = (seq >> 8) & 0xFF, seq & 0xFF
        if self.proto == "icmp":
            return pkt.make_icmp_echo(payload, seq=seq,
                                      src_mac=self.src_mac,
                                      dst_mac=self.dst_mac)
        return pkt.make_udp(payload, dport=self.dport,
                            src_mac=self.src_mac, dst_mac=self.dst_mac)

    def poll(self, now: int) -> List[np.ndarray]:
        if self.seq >= self.count and self.sent_at < 0:
            return []
        if self.sent_at >= 0:
            if now - self.sent_at < self.timeout:
                return []
            self.timeouts += 1                 # lost ping or pong: refire
        else:
            self.first_sent = now
        self.sent_at = now
        return [self._frame(self.seq)]

    def on_host_frames(self, frames: List[np.ndarray], now: int) -> None:
        if self.sent_at < 0:
            return
        for f in frames:
            is_pong = (f[pkt.IP_PROTO] == pkt.IPPROTO_ICMP
                       and f[pkt.ICMP_TYPE] == pkt.ICMP_ECHO_REPLY) \
                if self.proto == "icmp" else \
                (f[pkt.IP_PROTO] == pkt.IPPROTO_UDP)
            # both echo payloads start at byte 42: the stamped seq ties the
            # pong to the outstanding ping (duplicates/late pongs ignored)
            echoed = (int(f[42]) << 8) | int(f[43]) if len(f) >= 44 else -1
            if is_pong and echoed == self.seq:
                # completion latency: measured from the FIRST transmission,
                # so retry delay after loss shows up in the number
                self.rtts.append(now - self.first_sent)
                self.seq += 1
                self.sent_at = -1
                break

    @property
    def done(self) -> bool:
        return self.seq >= self.count

    def snapshot(self) -> dict:
        return dict(seq=self.seq, sent_at=self.sent_at,
                    first_sent=self.first_sent,
                    rtts=list(self.rtts), timeouts=self.timeouts)

    def restore(self, snap: dict) -> None:
        self.seq = snap["seq"]
        self.sent_at = snap["sent_at"]
        self.first_sent = snap["first_sent"]
        self.rtts = list(snap["rtts"])
        self.timeouts = snap["timeouts"]


class Node:
    """One endpoint of the fabric: NIC + host engines + a MAC address.

    Pass ``nic`` to share one :class:`SpinNIC` (and its jitted datapath)
    between several nodes with identical contexts — a ``SpinNIC`` holds no
    per-node mutable state, so an N-rank fabric compiles the step function
    once instead of N times.  ``contexts``/``host_bytes``/``batch`` are
    ignored when ``nic`` is given.
    """

    def __init__(self, name: str, mac: bytes,
                 contexts: Optional[Sequence] = None,
                 host_bytes: int = 1 << 20,
                 batch: int = 32,
                 engines: Sequence[HostEngine] = (),
                 nic: Optional[spin_nic.SpinNIC] = None):
        self.name = name
        self.mac = bytes(mac)
        if nic is None:
            assert contexts is not None, "need contexts or a prebuilt nic"
            nic = spin_nic.SpinNIC(list(contexts), host_bytes=host_bytes,
                                   batch=batch)
        self.nic = nic
        contexts = nic.contexts
        self.batch = nic.batch
        # any installed handler may push_counter; skip the per-tick FIFO
        # drain (a blocking device read) only when no context runs handlers
        # at all (null-context sender/client nodes — the hot-loop case)
        self._completes = any(
            c.message_mode or c.header is not H.default_handler
            or c.packet is not H.default_handler
            or c.tail is not H.default_handler
            for c in contexts)
        self.state = self.nic.init_state()
        self.engines: List[HostEngine] = list(engines)
        # drained completion FIFO values, in arrival order.  SLMP pushes
        # are at-least-once (one per EOM *arrival* — see slmp_tail_handler)
        # so duplicates appear under loss; membership, not equality, is the
        # meaningful check.
        self.completions: List[int] = []

    def tick_idle(self, now: int) -> List[np.ndarray]:
        """Advance one tick with an empty ingress batch.  The NIC step is
        skipped entirely: with no valid frames the datapath is a no-op on
        every piece of state except the cycle counter (which nothing
        reads), and the jitted step costs the same whether the batch is
        empty or full — skipping it is what makes a mostly-idle fabric
        tick cheap.  Host engines still poll (timers, retransmits)."""
        out: List[np.ndarray] = []
        for e in self.engines:
            out.extend(e.poll(now))
        return out

    def tick(self, ingress: pkt.PacketBatch, now: int) -> List[np.ndarray]:
        """Advance one tick: run the NIC on the delivered ingress batch,
        hand host-path frames and completions to the engines, and return
        every frame this node puts on the wire."""
        self.state, egress, to_host = self.nic.step(self.state, ingress)

        # host datapath: deliver non-matching frames to the engines
        th_valid = np.asarray(to_host.valid)
        if th_valid.any():
            data = np.asarray(to_host.data)
            lens = np.asarray(to_host.length)
            host_frames = [data[i, :lens[i]].copy()
                           for i in np.flatnonzero(th_valid)]
            for e in self.engines:
                e.on_host_frames(host_frames, now)

        # completion notifications
        if self._completes:
            comp, self.state = self.nic.pop_counters(self.state,
                                                     slmp.COMPLETION_QUEUE)
            if len(comp):
                self.completions.extend(int(c) for c in comp)
                for e in self.engines:
                    e.on_completions(comp, now)

        # outbound = handler egress + engine-generated frames
        out: List[np.ndarray] = []
        eg_valid = np.asarray(egress.valid)
        if eg_valid.any():
            data = np.asarray(egress.data)
            lens = np.asarray(egress.length)
            out.extend(data[i, :lens[i]].copy()
                       for i in np.flatnonzero(eg_valid))
        for e in self.engines:
            out.extend(e.poll(now))
        return out

    @property
    def done(self) -> bool:
        return all(e.done for e in self.engines)

    def reset(self, engines: Optional[Sequence[HostEngine]] = None) -> None:
        """Fresh NIC state (and optionally new engines) without recompiling
        the jitted datapath — sweep benchmarks reuse one Node per config."""
        self.state = self.nic.init_state()
        self.completions = []
        if engines is not None:
            self.engines = list(engines)

    def read_host(self, base: int, nbytes: int) -> np.ndarray:
        return self.nic.read_host(self.state, base, nbytes)

    def write_expect(self, idx: int, msg_id: int) -> None:
        """Host MMIO write into the NIC's expected-msg_id slot table."""
        self.state = self.nic.write_expect(self.state, idx, msg_id)

    def snapshot(self) -> dict:
        # NIC step donates its input state: snapshots must own their buffers
        return dict(nic=jax.tree.map(jnp.copy, self.state),
                    engines=[e.snapshot() for e in self.engines],
                    completions=list(self.completions))

    def restore(self, snap: dict) -> None:
        self.state = jax.tree.map(jnp.copy, snap["nic"])
        for e, s in zip(self.engines, snap["engines"]):
            e.restore(s)
        self.completions = list(snap["completions"])
