"""The fabric: N nodes wired together through lossy links, MAC-routed.

Topology model: every node owns one *ingress link* (its wire).  A frame
leaving any node is routed by destination MAC onto the target node's
ingress link, where the link model applies loss / duplication / latency /
reordering; ``latency`` ticks later the frame surfaces in the target's
ingress batch.  One :meth:`Fabric.tick` advances every node by one NIC
step plus one link round — discrete-event at batch granularity, the same
granularity as ``SpinNIC.step``.

**Hot loop.** When every link shares one config and every node one batch
size (the common case — an MPI job, a benchmark sweep), the per-tick work
is batched across nodes: one vmapped ``pop`` drains all N links in a
single device call, destination MACs of all egress frames are matched
against the node-MAC matrix in one vectorized compare (no per-frame
``bytes()``/dict hops), and all routed traffic lands on the links through
one vmapped ``push``.  Nodes whose link delivered nothing this tick skip
the NIC step entirely (``Node.tick_idle``) — on a mostly-idle fabric the
tick cost is one pop, N cheap engine polls, and at most one push.
Heterogeneous ``link_cfgs`` / batch sizes fall back to the per-link loop.

The whole system state (per-node ``NICState``, per-link ``LinkState``,
host-engine counters, the tick clock, the PRNG key) is captured by
:meth:`checkpoint` and restored by :meth:`restore` — a fabric run is a
pure function of (initial state, seed), like a single NIC.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet as pkt
from repro.net import link as linklib
from repro.net.node import Node


@functools.partial(jax.jit, static_argnums=(0, 1))
def _pop_all(cfg: linklib.LinkConfig, n: int, states, now):
    """Drain all N links at once: one device call instead of N."""
    return jax.vmap(lambda s: linklib._pop(s, now, n))(states)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _push_all(cfg: linklib.LinkConfig, states, keys, batch, now):
    """Admit per-node egress batches onto all N links in one device call
    (empty lanes carry ``valid=False`` rows and only consume PRNG)."""
    return jax.vmap(
        lambda s, k, b: linklib._push(cfg, s, k, b, now))(states, keys, batch)


class Fabric:
    def __init__(self, nodes: Sequence[Node],
                 link_cfg: linklib.LinkConfig = linklib.LinkConfig(),
                 link_cfgs: Optional[Sequence[linklib.LinkConfig]] = None,
                 seed: int = 0):
        """``link_cfgs`` (one per node, ingress side) overrides the shared
        ``link_cfg`` when per-node asymmetry is wanted."""
        self.nodes: List[Node] = list(nodes)
        cfgs = list(link_cfgs) if link_cfgs is not None else \
            [link_cfg] * len(self.nodes)
        assert len(cfgs) == len(self.nodes)
        self.links = [linklib.Link(c) for c in cfgs]
        self.key = jax.random.PRNGKey(seed)
        self.now = 0
        self.unroutable = 0
        self._by_mac: Dict[bytes, int] = {
            n.mac: i for i, n in enumerate(self.nodes)}
        # (N, 6) MAC matrix for the vectorized routing compare
        self._mac_mat = np.stack(
            [np.frombuffer(n.mac, np.uint8) for n in self.nodes])
        # uniform fast path: identical link cfgs + identical node batches
        self._uniform = (len(set(cfgs)) == 1
                         and len({n.batch for n in self.nodes}) == 1)
        if self._uniform:
            self._cfg0 = cfgs[0]
            self._batch0 = self.nodes[0].batch
            self._stack = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[l.init_state() for l in self.links])
            self.link_states = None
        else:
            self._stack = None
            self.link_states = [l.init_state() for l in self.links]

    # ---------------------------------------------------------------- tick
    def tick(self) -> None:
        if self._uniform:
            self._tick_batched()
        else:
            self._tick_loop()
        self.now += 1

    def _route(self, frames: List[np.ndarray],
               outbound: List[List[np.ndarray]]) -> None:
        """Vectorized MAC routing: match every frame's destination MAC
        against the node matrix in one compare."""
        if not frames:
            return
        dst6 = np.stack([f[pkt.ETH_DST:pkt.ETH_DST + 6] for f in frames])
        hit = (dst6[:, None, :] == self._mac_mat[None, :, :]).all(-1)
        dest = hit.argmax(1)
        ok = hit.any(1)
        self.unroutable += int((~ok).sum())
        for i in np.flatnonzero(ok):
            outbound[dest[i]].append(frames[i])

    def _tick_batched(self) -> None:
        now = self.now
        n_nodes = len(self.nodes)
        self._stack, ing = _pop_all(self._cfg0, self._batch0,
                                    self._stack, now)
        # one host sync for the whole fabric: materialize the delivered
        # batches as numpy (a few tens of KB) — per-node numpy slices are
        # free, where N eager device slices would each pay a dispatch
        valid = np.asarray(ing.valid)
        busy = valid.any(1)
        if busy.any():
            data, length = np.asarray(ing.data), np.asarray(ing.length)
        outbound: List[List[np.ndarray]] = [[] for _ in self.nodes]
        for i, node in enumerate(self.nodes):
            if busy[i]:
                frames = node.tick(pkt.PacketBatch(
                    data[i], length[i], valid[i]), now)
            else:
                frames = node.tick_idle(now)
            self._route(frames, outbound)
        self._flush_outbound(outbound)

    def _flush_outbound(self, outbound: List[List[np.ndarray]]) -> None:
        """Admit routed per-node egress onto all links in one vmapped
        push (stacked to (N, P, MTU), P a power of two so the jitted push
        compiles O(log) shapes)."""
        counts = [len(o) for o in outbound]
        if not any(counts):
            return
        n_nodes = len(self.nodes)
        p = 1 << max(0, (max(counts) - 1).bit_length())
        data = np.zeros((n_nodes, p, pkt.MTU), np.uint8)
        length = np.zeros((n_nodes, p), np.int32)
        ok = np.zeros((n_nodes, p), bool)
        for j, frames in enumerate(outbound):
            for k, f in enumerate(frames):
                data[j, k, :len(f)] = f
                length[j, k] = len(f)
                ok[j, k] = True
        self.key, sub = jax.random.split(self.key)
        keys = jax.random.split(sub, n_nodes)
        self._stack = _push_all(
            self._cfg0, self._stack, keys,
            pkt.PacketBatch(jnp.asarray(data), jnp.asarray(length),
                            jnp.asarray(ok)), self.now)

    def _tick_loop(self) -> None:
        """Per-link fallback for heterogeneous link configs/batches."""
        now = self.now
        outbound: List[List[np.ndarray]] = [[] for _ in self.nodes]
        for i, node in enumerate(self.nodes):
            self.link_states[i], ingress = self.links[i].pop(
                self.link_states[i], now, node.batch)
            frames = node.tick(ingress, now)
            self._route(frames, outbound)
        for j, frames in enumerate(outbound):
            if not frames:
                continue
            n = 1 << max(0, (len(frames) - 1).bit_length())
            self.key, sub = jax.random.split(self.key)
            self.link_states[j] = self.links[j].push(
                self.link_states[j], sub, pkt.stack_frames(frames, n=n), now)

    def run(self, max_ticks: int = 10_000, until=None) -> int:
        """Tick until ``until()`` (default: every node's engines done and
        all links drained) or ``max_ticks``.  Returns ticks executed."""
        if until is None:
            def until():
                if not all(n.done for n in self.nodes):
                    return False
                if self._uniform:
                    return not bool(
                        np.asarray(self._stack.occupied).any())
                return not any(bool(np.asarray(s.occupied).any())
                               for s in self.link_states)
        t0 = self.now
        while self.now - t0 < max_ticks and not until():
            self.tick()
        return self.now - t0

    def reset(self, seed: int = 0) -> None:
        """Fresh links/clock/PRNG (node NIC states reset via Node.reset)."""
        if self._uniform:
            self._stack = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[l.init_state() for l in self.links])
        else:
            self.link_states = [l.init_state() for l in self.links]
        self.key = jax.random.PRNGKey(seed)
        self.now = 0
        self.unroutable = 0

    # ---------------------------------------------------------- observability
    def node(self, name: str) -> Node:
        return next(n for n in self.nodes if n.name == name)

    def _per_link_states(self) -> List[linklib.LinkState]:
        if self._uniform:
            return [jax.tree.map(lambda a, i=i: a[i], self._stack)
                    for i in range(len(self.nodes))]
        return self.link_states

    def link_stats(self) -> List[dict]:
        if self._uniform:
            # one transfer per counter for the whole fabric
            names = ("pushed", "lost", "overflowed", "duplicated",
                     "reordered", "delivered", "deferred")
            cols = {k: np.asarray(getattr(self._stack, k)) for k in names}
            return [{k: int(cols[k][i]) for k in names}
                    for i in range(len(self.nodes))]
        return [l.stats(s) for l, s in zip(self.links, self.link_states)]

    def stats(self) -> dict:
        """Fabric-wide health: unroutable frames (frames whose destination
        MAC matches no node — silently dropped by real switches, loudly
        counted here) plus per-link wire and stall counters."""
        links = self.link_stats()
        totals = {f"{k}_total": sum(l[k] for l in links)
                  for k in ("lost", "overflowed", "deferred", "delivered")}
        return dict(unroutable=self.unroutable, links=links, **totals)

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> dict:
        return dict(
            now=self.now,
            key=jnp.copy(self.key),
            unroutable=self.unroutable,
            links=[jax.tree.map(jnp.copy, s)
                   for s in self._per_link_states()],
            nodes=[n.snapshot() for n in self.nodes],
        )

    def restore(self, snap: dict) -> None:
        self.now = snap["now"]
        self.key = jnp.copy(snap["key"])
        self.unroutable = snap["unroutable"]
        if self._uniform:
            self._stack = jax.tree.map(
                lambda *xs: jnp.stack([jnp.copy(x) for x in xs]),
                *snap["links"])
        else:
            self.link_states = [jax.tree.map(jnp.copy, s)
                                for s in snap["links"]]
        for n, s in zip(self.nodes, snap["nodes"]):
            n.restore(s)
