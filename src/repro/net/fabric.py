"""The fabric: N nodes wired together through lossy links, MAC-routed.

Topology model: every node owns one *ingress link* (its wire).  A frame
leaving any node is routed by destination MAC onto the target node's
ingress link, where the link model applies loss / duplication / latency /
reordering; ``latency`` ticks later the frame surfaces in the target's
ingress batch.  One :meth:`Fabric.tick` advances every node by one NIC
step plus one link round — discrete-event at batch granularity, the same
granularity as ``SpinNIC.step``.

The whole system state (per-node ``NICState``, per-link ``LinkState``,
host-engine counters, the tick clock, the PRNG key) is captured by
:meth:`checkpoint` and restored by :meth:`restore` — a fabric run is a
pure function of (initial state, seed), like a single NIC.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet as pkt
from repro.net import link as linklib
from repro.net.node import Node


class Fabric:
    def __init__(self, nodes: Sequence[Node],
                 link_cfg: linklib.LinkConfig = linklib.LinkConfig(),
                 link_cfgs: Optional[Sequence[linklib.LinkConfig]] = None,
                 seed: int = 0):
        """``link_cfgs`` (one per node, ingress side) overrides the shared
        ``link_cfg`` when per-node asymmetry is wanted."""
        self.nodes: List[Node] = list(nodes)
        cfgs = list(link_cfgs) if link_cfgs is not None else \
            [link_cfg] * len(self.nodes)
        assert len(cfgs) == len(self.nodes)
        self.links = [linklib.Link(c) for c in cfgs]
        self.link_states = [l.init_state() for l in self.links]
        self.key = jax.random.PRNGKey(seed)
        self.now = 0
        self.unroutable = 0
        self._by_mac: Dict[bytes, int] = {
            n.mac: i for i, n in enumerate(self.nodes)}

    # ---------------------------------------------------------------- tick
    def tick(self) -> None:
        now = self.now
        outbound: List[List[np.ndarray]] = [[] for _ in self.nodes]

        # 1) every node consumes what its link delivers this tick
        for i, node in enumerate(self.nodes):
            self.link_states[i], ingress = self.links[i].pop(
                self.link_states[i], now, node.batch)
            frames = node.tick(ingress, now)
            # 2) route by destination MAC
            for f in frames:
                dst = bytes(f[pkt.ETH_DST:pkt.ETH_DST + 6])
                j = self._by_mac.get(dst)
                if j is None:
                    self.unroutable += 1
                    continue
                outbound[j].append(f)

        # 3) push routed traffic onto the target links (padded to a power
        #    of two so the jitted link push compiles O(log) shapes, not one
        #    per distinct frame count)
        for j, frames in enumerate(outbound):
            if not frames:
                continue
            n = 1 << max(0, (len(frames) - 1).bit_length())
            self.key, sub = jax.random.split(self.key)
            self.link_states[j] = self.links[j].push(
                self.link_states[j], sub, pkt.stack_frames(frames, n=n), now)
        self.now += 1

    def run(self, max_ticks: int = 10_000, until=None) -> int:
        """Tick until ``until()`` (default: every node's engines done and
        all links drained) or ``max_ticks``.  Returns ticks executed."""
        if until is None:
            def until():
                return all(n.done for n in self.nodes) and not any(
                    bool(np.asarray(s.occupied).any())
                    for s in self.link_states)
        t0 = self.now
        while self.now - t0 < max_ticks and not until():
            self.tick()
        return self.now - t0

    def reset(self, seed: int = 0) -> None:
        """Fresh links/clock/PRNG (node NIC states reset via Node.reset)."""
        self.link_states = [l.init_state() for l in self.links]
        self.key = jax.random.PRNGKey(seed)
        self.now = 0
        self.unroutable = 0

    # ---------------------------------------------------------- observability
    def node(self, name: str) -> Node:
        return next(n for n in self.nodes if n.name == name)

    def link_stats(self) -> List[dict]:
        return [l.stats(s) for l, s in zip(self.links, self.link_states)]

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> dict:
        return dict(
            now=self.now,
            key=jnp.copy(self.key),
            unroutable=self.unroutable,
            links=[jax.tree.map(jnp.copy, s) for s in self.link_states],
            nodes=[n.snapshot() for n in self.nodes],
        )

    def restore(self, snap: dict) -> None:
        self.now = snap["now"]
        self.key = jnp.copy(snap["key"])
        self.unroutable = snap["unroutable"]
        self.link_states = [jax.tree.map(jnp.copy, s)
                            for s in snap["links"]]
        for n, s in zip(self.nodes, snap["nodes"]):
            n.restore(s)
