"""The link model: a lossy, reordering, duplicating wire with latency.

A :class:`Link` owns a fixed-capacity in-flight buffer (``LinkState``, a
registered pytree — checkpointable exactly like ``NICState``).  Both
operations are pure jitted functions:

  ``push(state, key, batch, now)``  — admit an egress ``PacketBatch``:
      each packet is independently dropped with probability ``loss``,
      duplicated with probability ``duplicate``, and stamped with a
      delivery tick ``now + latency + U[0, jitter]`` (+ an extra
      ``reorder_delay`` with probability ``reorder`` — late-stamped
      packets overtake each other, which is how reordering emerges).
  ``pop(state, now, n)``            — extract up to ``n`` packets whose
      delivery tick has passed, as an ingress ``PacketBatch``.

Randomness comes only from the PRNG key: the same key produces the same
loss pattern, so every fabric experiment is exactly reproducible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet as pkt


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Static link parameters (latencies in fabric ticks)."""
    loss: float = 0.0           # per-packet drop probability
    duplicate: float = 0.0      # per-packet duplication probability
    latency: int = 1            # base one-way latency, ticks (>= 1)
    jitter: int = 0             # uniform extra delay in [0, jitter]
    reorder: float = 0.0        # prob. of an extra reorder_delay penalty
    reorder_delay: int = 3
    capacity: int = 512         # in-flight buffer slots (overflow drops)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LinkState:
    data: jax.Array        # (CAP, MTU) uint8 in-flight frames
    length: jax.Array      # (CAP,) int32
    deliver_at: jax.Array  # (CAP,) int32 delivery tick
    occupied: jax.Array    # (CAP,) bool
    pushed: jax.Array      # () int32 — packets offered to the link
    lost: jax.Array        # () int32 — dropped by the loss process
    overflowed: jax.Array  # () int32 — dropped on buffer overflow
    duplicated: jax.Array  # () int32
    reordered: jax.Array   # () int32 — packets given the reorder penalty
    delivered: jax.Array   # () int32
    deferred: jax.Array    # () int32 — ready packets a pop left behind
    #                          because the ingress batch was full (per-link
    #                          stall pressure: the NIC, not the wire, is
    #                          the bottleneck when this grows)

    def tree_flatten(self):
        return (self.data, self.length, self.deliver_at, self.occupied,
                self.pushed, self.lost, self.overflowed, self.duplicated,
                self.reordered, self.delivered, self.deferred), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_state(capacity: int) -> LinkState:
    return LinkState(
        data=jnp.zeros((capacity, pkt.MTU), jnp.uint8),
        length=jnp.zeros((capacity,), jnp.int32),
        deliver_at=jnp.zeros((capacity,), jnp.int32),
        occupied=jnp.zeros((capacity,), bool),
        pushed=jnp.zeros((), jnp.int32),
        lost=jnp.zeros((), jnp.int32),
        overflowed=jnp.zeros((), jnp.int32),
        duplicated=jnp.zeros((), jnp.int32),
        reordered=jnp.zeros((), jnp.int32),
        delivered=jnp.zeros((), jnp.int32),
        deferred=jnp.zeros((), jnp.int32),
    )


@functools.partial(jax.jit, static_argnums=(0,))
def _push(cfg: LinkConfig, state: LinkState, key: jax.Array,
          batch: pkt.PacketBatch, now) -> LinkState:
    n = batch.n
    k_loss, k_dup, k_jit, k_reo = jax.random.split(key, 4)

    survives = batch.valid & (
        jax.random.uniform(k_loss, (n,)) >= cfg.loss)
    dup = survives & (jax.random.uniform(k_dup, (n,)) < cfg.duplicate)

    # candidates = originals + duplicates, each with its own delay sample
    cand_valid = jnp.concatenate([survives, dup])
    delay = jnp.asarray(cfg.latency, jnp.int32) + (
        jax.random.randint(k_jit, (2 * n,), 0, cfg.jitter + 1)
        if cfg.jitter > 0 else 0)
    reo = jnp.zeros((2 * n,), bool)
    if cfg.reorder > 0.0:
        reo = jax.random.uniform(k_reo, (2 * n,)) < cfg.reorder
        delay = delay + jnp.where(reo, cfg.reorder_delay, 0)
    deliver_at = jnp.asarray(now, jnp.int32) + delay

    # scatter candidates into free slots (FIFO over the slot array)
    cap = state.occupied.shape[0]
    cand_rank = jnp.cumsum(cand_valid.astype(jnp.int32)) - 1
    n_free = (~state.occupied).sum()
    fits = cand_valid & (cand_rank < n_free)
    # slot index for the r-th candidate = index of the r-th free slot
    slot_of_rank = jnp.argsort(state.occupied, stable=True)   # free first
    slot = jnp.where(fits, slot_of_rank[jnp.minimum(cand_rank, cap - 1)],
                     cap)                                     # cap -> drop
    cand_data = jnp.concatenate([batch.data, batch.data])
    cand_len = jnp.concatenate([batch.length, batch.length])
    data = state.data.at[slot].set(cand_data, mode="drop")
    length = state.length.at[slot].set(cand_len, mode="drop")
    dat = state.deliver_at.at[slot].set(
        jnp.broadcast_to(deliver_at, (2 * n,)), mode="drop")
    occupied = state.occupied.at[slot].set(True, mode="drop")

    return LinkState(
        data=data, length=length, deliver_at=dat, occupied=occupied,
        pushed=state.pushed + batch.valid.sum().astype(jnp.int32),
        lost=state.lost + (batch.valid & ~survives).sum().astype(jnp.int32),
        overflowed=state.overflowed
        + (cand_valid & ~fits).sum().astype(jnp.int32),
        duplicated=state.duplicated + dup.sum().astype(jnp.int32),
        reordered=state.reordered
        + (cand_valid & reo).sum().astype(jnp.int32),
        delivered=state.delivered,
        deferred=state.deferred,
    )


@functools.partial(jax.jit, static_argnums=(2,))
def _pop(state: LinkState, now, n: int
         ) -> Tuple[LinkState, pkt.PacketBatch]:
    ready = state.occupied & (state.deliver_at <= jnp.asarray(now, jnp.int32))
    rank = jnp.cumsum(ready.astype(jnp.int32)) - 1
    take = ready & (rank < n)
    order = jnp.argsort(~take, stable=True)[:n]        # taken slots first
    out = pkt.PacketBatch(data=state.data[order],
                          length=state.length[order],
                          valid=take[order])
    new = dataclasses.replace(
        state, occupied=state.occupied & ~take,
        delivered=state.delivered + take.sum().astype(jnp.int32),
        deferred=state.deferred + (ready & ~take).sum().astype(jnp.int32))
    return new, out


class Link:
    """One directed ingress pipe: every frame headed to a node traverses
    its link before the NIC sees it."""

    def __init__(self, cfg: LinkConfig = LinkConfig()):
        self.cfg = cfg

    def init_state(self) -> LinkState:
        return make_state(self.cfg.capacity)

    def push(self, state: LinkState, key: jax.Array, batch: pkt.PacketBatch,
             now: int) -> LinkState:
        return _push(self.cfg, state, key, batch, now)

    def pop(self, state: LinkState, now: int, n: int
            ) -> Tuple[LinkState, pkt.PacketBatch]:
        return _pop(state, now, n)

    def stats(self, state: LinkState) -> dict:
        return {k: int(getattr(state, k)) for k in
                ("pushed", "lost", "overflowed", "duplicated", "reordered",
                 "delivered", "deferred")}
