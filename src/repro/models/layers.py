"""Shared neural-net layers: norms, rotary embeddings, MLP variants.

All layers are pure functions over parameter pytrees (dicts).  Init
functions only build ``jax.ShapeDtypeStruct``-compatible shapes through
``jax.eval_shape`` when used by the dry-run, so nothing here may allocate
eagerly at import time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(orig)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections=None) -> jax.Array:
    """Qwen2-VL M-RoPE: positions (3, B, S) = (temporal, h, w); the head
    dim's frequency slots are split into three sections, each rotated by
    its own position component.  sections are in *frequency pairs* and
    must sum to head_dim/2.  Default split = (1/4, 3/8, 3/8) of the pairs,
    i.e. (16, 24, 24) for head_dim 128 — the Qwen2-VL configuration."""
    d = x.shape[-1]
    if sections is None:
        t = d // 8
        h = (d // 2 - t) // 2
        sections = (t, h, d // 2 - t - h)
    assert sum(sections) == d // 2, (sections, d)
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)        # (D/2,)
    # component id per frequency slot
    comp = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos = positions.astype(jnp.float32)                           # (3,B,S)
    pos_per_slot = jnp.take(pos, jnp.asarray(comp), axis=0)       # (D/2,B,S)
    angles = jnp.moveaxis(pos_per_slot, 0, -1) * freqs            # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal absolute position embedding table."""
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / (10000 ** (dim / d))
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out


# --------------------------------------------------------------------- MLP
def mlp_init(key, cfg: ModelConfig, d_ff: int) -> Params:
    d, dt = cfg.d_model, _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d))
    s_out = float(1.0 / np.sqrt(d_ff))
    p = {"up": jax.random.normal(k1, (d, d_ff), dt) * s_in,
         "down": jax.random.normal(k2, (d_ff, d), dt) * s_out}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["gate"] = jax.random.normal(k3, (d, d_ff), dt) * s_in
    return p


def mlp_apply(p: Params, x: jax.Array, kind: str) -> jax.Array:
    up = x @ p["up"]
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * up
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["gate"], approximate=True) * up
    elif kind == "squared_relu":                     # nemotron-4
        h = jnp.square(jax.nn.relu(up))
    elif kind == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(kind)
    return h @ p["down"]


# --------------------------------------------------------------- embedding
def embed_init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    v = cfg.padded_vocab
    p = {"tok": jax.random.normal(k1, (v, cfg.d_model), dt) * 0.02}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            k2, (cfg.d_model, v), dt) * float(1.0 / np.sqrt(cfg.d_model))
    return p


def embed_tokens(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(p: Params, x: jax.Array, tie: bool,
              out_dtype=jnp.float32, true_vocab: int = 0) -> jax.Array:
    """Logits over the (possibly padded) vocab; padded lanes get -1e9 so
    the CE logsumexp ignores them."""
    w = p["tok"].T if tie else p["lm_head"]
    logits = (x @ w).astype(out_dtype)
    v = w.shape[-1]
    if true_vocab and true_vocab < v:
        lane = jnp.arange(v)
        logits = jnp.where(lane < true_vocab, logits,
                           jnp.asarray(-1e9, out_dtype))
    return logits
