"""Mixture-of-Experts layer with sort-based dispatch (qwen2-moe, kimi-k2).

Dispatch is the capacity-bounded sort/scatter formulation: token→expert
assignments are sorted by expert id, each expert keeps up to C tokens in a
dense (E, C, d) buffer, expert FFNs run as one batched einsum with the
expert dimension sharded over the ``model`` mesh axis (expert parallelism
— under pjit the gather/scatter of tokens to expert shards lowers to
all-to-all collectives), and results are combined with the router weights.
Dropped tokens (rank ≥ C) fall through with weight renormalization.

The expert count is zero-padded to a multiple of 16 so EP divides the
model axis (padded experts receive no tokens: the router only scores real
experts).  Shared experts (qwen2-moe: 4×1408, kimi-k2: 1×2048) run densely
for every token.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

EP_PAD_MULTIPLE = 16


def padded_experts(n_experts: int) -> int:
    return ((n_experts + EP_PAD_MULTIPLE - 1) // EP_PAD_MULTIPLE) \
        * EP_PAD_MULTIPLE


def moe_init(key, cfg: ModelConfig):
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    e_pad = padded_experts(cfg.n_experts)
    ff = cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    s_in, s_out = float(1 / np.sqrt(d)), float(1 / np.sqrt(ff))
    p = {
        "router": jax.random.normal(ks[0], (d, cfg.n_experts),
                                    jnp.float32) * s_in,
        "up": jax.random.normal(ks[1], (e_pad, d, ff), dt) * s_in,
        "gate": jax.random.normal(ks[2], (e_pad, d, ff), dt) * s_in,
        "down": jax.random.normal(ks[3], (e_pad, ff, d), dt) * s_out,
    }
    if cfg.n_shared_experts:
        ffs = cfg.d_ff_shared * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "up": jax.random.normal(k1, (d, ffs), dt) * s_in,
            "gate": jax.random.normal(k2, (d, ffs), dt) * s_in,
            "down": jax.random.normal(k3, (ffs, d), dt) * float(1 / np.sqrt(ffs)),
        }
    return p


def moe_apply(p, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float = None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (y, aux_loss).

    capacity_factor None -> cfg.moe_capacity_factor (training default);
    decode paths pass n_experts (drop-free: a one-token step must never
    lose its expert)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    e_pad = p["up"].shape[0]
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                 # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32).sum(1), axis=0)
    mean_prob = probs.mean(axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(density / k * mean_prob)

    # ---- sort assignments by expert
    tk = t * k
    flat_e = top_e.reshape(tk)
    flat_w = top_w.reshape(tk)
    flat_t = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    # rank of each assignment within its expert
    counts = jnp.bincount(flat_e, length=e_pad)            # (E_pad,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(tk) - starts[se]

    capacity = int(np.ceil(tk / e * capacity_factor))
    keep = rank < capacity
    slot = jnp.where(keep, se * capacity + rank, e_pad * capacity)

    # ---- dispatch: (E_pad * C, d) buffer
    buf = jnp.zeros((e_pad * capacity, d), x.dtype)
    buf = buf.at[slot].set(xt[st], mode="drop")
    buf = buf.reshape(e_pad, capacity, d)

    # ---- expert FFN (EP: e dimension sharded over the model axis)
    h = jnp.einsum("ecd,edf->ecf", buf, p["gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"],
                   preferred_element_type=jnp.float32)
    act = (jax.nn.silu(h) * u).astype(x.dtype)
    out = jnp.einsum("ecf,efd->ecd", act, p["down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # ---- combine: gather back and weight
    out_flat = out.reshape(e_pad * capacity, d)
    safe_slot = jnp.minimum(slot, e_pad * capacity - 1)
    y_sorted = jnp.where(keep[:, None], out_flat[safe_slot], 0)
    y = jnp.zeros((t, d), x.dtype)
    y = y.at[st].add(y_sorted * sw[:, None].astype(x.dtype))

    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["gate"]) * (xt @ sp["up"])
        y = y + hs @ sp["down"]
    return y.reshape(b, s, d), aux
