"""GQA attention: blockwise-streaming (flash-style) prefill/train path and
O(cache) decode path, with full / sliding-window / bidirectional / cross
variants.

The train/prefill path never materializes an (S × S) score matrix: it
scans over KV blocks with a running-max online softmax (f32 accumulators),
so activation memory is O(S · block) — required for the 32 k-token prefill
shapes and the long-context cells of the assignment.  Sliding-window
layers bound compute too: each query block attends to a
``dynamic_slice``-d KV span of width ``window + block``, making local
attention O(S · window) — this is what lets gemma3/recurrentgemma run the
524 k decode cell.

GQA is expressed by folding query heads into groups over the KV heads;
with model-axis sharding on the KV head dimension the same code serves
MHA (kv == heads) down to MQA (kv == 1, replicated KV).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L

NEG_INF = -1e30

# Roofline/dry-run mode: unroll the q/kv block loops statically instead of
# lax.map/lax.scan, so compiled.cost_analysis() counts every block (scan
# bodies are otherwise costed once) AND statically skips fully-masked
# blocks — giving exact sparse FLOP counts for causal/windowed attention.
# Runtime semantics are identical; launch/dryrun.py flips this before
# lowering.  Never enabled on the training/serving hot path.
STATIC_BLOCKS = False


def attn_init(key, cfg: ModelConfig, cross: bool = False):
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = float(1.0 / np.sqrt(d))
    p = {
        "wq": jax.random.normal(kq, (d, cfg.q_dim), dt) * s,
        "wk": jax.random.normal(kk, (d, cfg.kv_dim), dt) * s,
        "wv": jax.random.normal(kv, (d, cfg.kv_dim), dt) * s,
        "wo": jax.random.normal(ko, (cfg.q_dim, d), dt) * s,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt)
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_x, positions, kv_positions):
    """Returns q (B,Sq,H,D), k/v (B,Sk,KV,D) with RoPE applied."""
    b, sq, _ = x.shape
    sk = kv_x.shape[1]
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, sq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = L.rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    if positions is not None and cfg.pos_kind == "rope":
        if cfg.mrope:
            q = L.apply_mrope(q, positions, cfg.rope_theta)
            k = L.apply_mrope(k, kv_positions, cfg.rope_theta)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa_block(q, k, v, mask, scale):
    """q (B,KV,G,bq,D); k/v (B,KV,bk,D); mask (bq,bk) or (B,1,1,bq,bk)."""
    s = jnp.einsum("bkgqd,bktd->bkgqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    return s


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_offset=0, kv_len: Optional[jax.Array] = None,
                        block_q: int = 512, block_k: int = 512,
                        score_dtype=jnp.float32):
    """Online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D).  H % KV == 0.
    causal: causal mask with query i at absolute position q_offset + i.
    window > 0: sliding window (attend to positions in (pos-window, pos]).
    kv_len: optional (B,) valid KV length (encoder padding / cache fill).
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = float(1.0 / np.sqrt(d))
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    nq, nk = sq_p // bq, sk_p // bk
    q = q * scale                    # fold softmax scale into q (one pass
    #                                  over O(S·d) instead of O(S²) scores)
    qb = q.reshape(b, nq, bq, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, KV, G, bq, D)
    kb = k.reshape(b, nk, bk, kvh, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, bk, kvh, d).transpose(1, 0, 3, 2, 4)
    k_valid = jnp.arange(sk_p)                       # (Sk,)

    def one_q_block(qi, qblk):
        q_pos = q_offset + qi * bq + jnp.arange(bq)   # (bq,) absolute

        if window > 0 and sk_p > (window // bk + 2) * bk:
            # local attention: slice only the needed KV span
            span = ((window + bq) // bk + 2) * bk
            start = jnp.clip(qi * bq + bq - span + (sk_p - sq_p), 0,
                             sk_p - span)
            ks = jax.lax.dynamic_slice_in_dim(
                k.reshape(b, sk_p, kvh, d), start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(
                v.reshape(b, sk_p, kvh, d), start, span, axis=1)
            kpos = start + jnp.arange(span)
            s = jnp.einsum("bqkgd,btkd->bkgqt",
                           qblk.transpose(0, 3, 1, 2, 4).reshape(
                               b, bq, kvh, g, d),
                           ks, preferred_element_type=jnp.float32)
            mask = (kpos[None, :] <= q_pos[:, None]) & \
                   (kpos[None, :] > q_pos[:, None] - window)
            if kv_len is not None:
                mask = mask[None] & (kpos[None, None, :] < kv_len[:, None,
                                                                  None])
                mask = mask[:, None, None]
            s = jnp.where(mask, s, NEG_INF)
            o = jax.nn.softmax(s, axis=-1).astype(score_dtype)
            out = jnp.einsum("bkgqt,btkd->bkgqd", o,
                             vs.astype(score_dtype),
                             preferred_element_type=jnp.float32)
            return out.astype(q.dtype)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kblk, vblk = inputs
            kpos = ki * bk + jnp.arange(bk)
            # the QK dot *emits* score_dtype (bf16 halves the S²-shaped
            # HBM traffic — accumulation inside the dot stays f32 on the
            # MXU); max/exp/sum statistics run in f32 via fused converts.
            s = _make_scores(qblk, kblk, q_pos, kpos)   # score_dtype
            new_m = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
            # convert+sub+exp+convert fuse: reads s (bf16), writes p (bf16)
            p = jnp.exp(s.astype(jnp.float32)
                        - new_m[..., None]).astype(score_dtype)
            corr = jnp.exp(m - new_m)
            l = l * corr + p.astype(jnp.float32).sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, vblk.astype(score_dtype),
                preferred_element_type=jnp.float32)
            return (new_m, l, acc), None

        def _make_scores(qblk_scaled, kblk, q_pos, kpos):
            # scale is pre-folded into q (one pass over the small tensor
            # instead of one pass over the S²-shaped scores)
            s = jnp.einsum("bkgqd,bktd->bkgqt", qblk_scaled, kblk,
                           preferred_element_type=score_dtype)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= kpos[None, :] > q_pos[:, None] - window
            neg = jnp.asarray(NEG_INF, score_dtype)
            s = jnp.where(mask[None, None, None], s, neg)
            if kv_len is not None:
                live = kpos[None, :] < kv_len[:, None]          # (B, bk)
                s = jnp.where(live[:, None, None, None, :], s, neg)
            return s

        m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, bq, d), jnp.float32)
        if STATIC_BLOCKS:
            carry = (m0, l0, a0)
            qi_static = int(qi)            # static under unrolled path
            for ki in range(nk):
                # static skip of fully-masked blocks (exact sparse flops)
                if causal and ki * bk > qi_static * bq + bq - 1:
                    continue
                if window > 0 and ki * bk + bk - 1 <= qi_static * bq \
                        - window:
                    continue
                carry, _ = kv_step(carry,
                                   (jnp.asarray(ki), kb[ki], vb[ki]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    if STATIC_BLOCKS:
        outs = jnp.stack([one_q_block(qi, qb[qi]) for qi in range(nq)])
    else:
        outs = jax.lax.map(lambda args: one_q_block(*args),
                           (jnp.arange(nq), qb))
    # (nq, B, KV, G, bq, D) -> (B, Sq, H, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq_p, h, d)
    return out[:, :sq]


def attend_train(p, cfg: ModelConfig, x, positions, *, kind: str,
                 enc_out=None, enc_positions=None, enc_len=None,
                 causal=True, return_kv: bool = False):
    """Full-sequence attention for train/prefill.  kind: attn|local|cross.
    Returns (B, S, d_model) or ((B,S,d), (k, v)) when return_kv."""
    sdt = jnp.dtype(cfg.attn_scores_dtype)
    if kind == "cross":
        q, k, v = _project_qkv(p, cfg, x, enc_out, None, None)
        out = blockwise_attention(q, k, v, causal=False, kv_len=enc_len,
                                  block_q=cfg.attn_block_q,
                                  block_k=cfg.attn_block_k,
                                  score_dtype=sdt)
    else:
        q, k, v = _project_qkv(p, cfg, x, x, positions, positions)
        out = blockwise_attention(
            q, k, v, causal=causal,
            window=cfg.window if kind == "local" else 0,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
            score_dtype=sdt)
    b, s = x.shape[:2]
    y = out.reshape(b, s, cfg.q_dim) @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


def fill_kv_cache(cache_k, cache_v, k, v, kind: str, window: int):
    """Write a prefill's K/V (B, S, KV, D) into a decode cache.

    Full attention: positions [0, S) go to slots [0, S).  Local: only the
    last ``window`` positions survive, at their ring-buffer slots
    (slot = pos % window), matching attend_decode's addressing."""
    s = k.shape[1]
    c = cache_k.shape[1]
    if kind == "local" and s > c:
        pos = jnp.arange(s - c, s)
        slots = pos % c
        cache_k = cache_k.at[:, slots].set(k[:, s - c:])
        cache_v = cache_v.at[:, slots].set(v[:, s - c:])
    else:
        n = min(s, c)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k[:, :n], 0, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v[:, :n], 0, axis=1)
    return cache_k, cache_v


def attend_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                  kind: str, positions=None):
    """Single-token decode.  x: (B, 1, d); cache_k/v: (B, C, KV, D) where
    C = max_seq (full) or window (local, ring buffer).  pos: () or (B,)
    absolute position of the new token.  Returns (y, cache_k, cache_v)."""
    b = x.shape[0]
    c = cache_k.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if positions is None:
        positions = pos[:, None]                      # (B, 1)
    q, k_new, v_new = _project_qkv(p, cfg, x, x, positions, positions)
    slot = pos % c if kind == "local" else pos        # ring buffer for local
    cache_k = jax.vmap(
        lambda ck, kn, s: jax.lax.dynamic_update_slice_in_dim(ck, kn, s, 0)
    )(cache_k, k_new, slot)
    cache_v = jax.vmap(
        lambda cv, vn, s: jax.lax.dynamic_update_slice_in_dim(cv, vn, s, 0)
    )(cache_v, v_new, slot)

    g = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, cfg.n_kv_heads, g, cfg.head_dim)
    s = jnp.einsum("bkgd,btkd->bkgt", qh, cache_k,
                   preferred_element_type=jnp.float32)
    s = s / np.sqrt(cfg.head_dim)
    # validity: absolute position of each cache slot
    slots = jnp.arange(c)[None, :]                    # (1, C)
    if kind == "local":
        # slot t holds absolute position: the most recent p <= pos with
        # p % c == t
        abs_pos = pos[:, None] - ((pos[:, None] - slots) % c)
        live = (abs_pos >= 0) & (abs_pos > pos[:, None] - cfg.window) & \
               (abs_pos <= pos[:, None])
    else:
        live = slots <= pos[:, None]
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    o = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", o.astype(cache_v.dtype), cache_v)
    y = out.reshape(b, 1, cfg.q_dim) @ p["wo"]
    return y, cache_k, cache_v


def attend_decode_cross(p, cfg: ModelConfig, x, enc_k, enc_v, enc_len):
    """Cross-attention during decode: enc K/V precomputed at prefill."""
    b = x.shape[0]
    q = (x @ p["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    g = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, cfg.n_kv_heads, g, cfg.head_dim)
    s = jnp.einsum("bkgd,btkd->bkgt", qh, enc_k,
                   preferred_element_type=jnp.float32)
    s = s / np.sqrt(cfg.head_dim)
    if enc_len is not None:
        live = jnp.arange(enc_k.shape[1])[None, :] < enc_len[:, None]
        s = jnp.where(live[:, None, None, :], s, NEG_INF)
    o = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", o.astype(enc_v.dtype), enc_v)
    return out.reshape(b, 1, cfg.q_dim) @ p["wo"]
