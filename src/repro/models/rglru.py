"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = sigmoid(W_r x_t + b_r)                 (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)                 (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training runs the linear recurrence with ``jax.lax.associative_scan``
(log-depth, O(S) work — this is the TPU-friendly counterpart of the
hardware's sequential recurrence).  Decode is a single O(width) update —
constant-size state, which is why this arch runs the 524 k decode cell.
The temporal block follows Griffin: conv1d(width 4) in front of the RG-LRU
and a GeLU-gated linear branch multiplied into its output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

_C = 8.0


def rglru_init(key, cfg: ModelConfig):
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    w = cfg.lru_width
    ks = jax.random.split(key, 6)
    s = float(1 / np.sqrt(d))
    sw = float(1 / np.sqrt(w))
    # Lambda init so that a ~ Uniform(0.9, 0.999)^c-ish (Griffin init)
    lam = -np.log(np.expm1(-np.log(np.random.RandomState(0)
                                   .uniform(0.9, 0.999, w)) / _C))
    return {
        "w_x": jax.random.normal(ks[0], (d, w), dt) * s,
        "w_gate": jax.random.normal(ks[1], (d, w), dt) * s,
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, w), dt)
        * float(1 / np.sqrt(cfg.conv_width)),
        "conv_b": jnp.zeros((w,), dt),
        "w_r": jax.random.normal(ks[3], (w, w), dt) * sw,
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": jax.random.normal(ks[4], (w, w), dt) * sw,
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.asarray(-lam, jnp.float32),
        "out": jax.random.normal(ks[5], (w, d), dt) * sw,
    }


def _gates(p, xb):
    r = jax.nn.sigmoid(xb @ p["w_r"] + p["b_r"].astype(xb.dtype))
    i = jax.nn.sigmoid(xb @ p["w_i"] + p["b_i"].astype(xb.dtype))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = (i * xb).astype(jnp.float32) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, gated_x


def _causal_conv(xb, w, b):
    k = w.shape[0]
    pad = jnp.pad(xb, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i:i + xb.shape[1], :] * w[i] for i in range(k)) + b


def rglru_apply_train(p, cfg: ModelConfig, x: jax.Array,
                      return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model) [, decode cache]."""
    xb_raw = x @ p["w_x"]
    xb = _causal_conv(xb_raw, p["conv_w"], p["conv_b"])
    a, gx = _gates(p, xb)                                   # (B,S,w) f32

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    y = (h.astype(x.dtype) * gate)
    out = y @ p["out"]
    if return_state:
        k = p["conv_w"].shape[0]
        tail = jnp.pad(xb_raw, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1):]
        return out, {"conv": tail, "h": h[:, -1]}
    return out


def rglru_decode_init(cfg: ModelConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


def rglru_apply_decode(p, cfg: ModelConfig, x, cache):
    """x: (B, 1, d_model) -> (y, cache)."""
    xb_raw = (x @ p["w_x"])[:, 0]                           # (B, w)
    win = jnp.concatenate([cache["conv"], xb_raw[:, None]], axis=1)
    xb = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    a, gx = _gates(p, xb)
    h = a * cache["h"] + gx
    gate = jax.nn.gelu((x @ p["w_gate"])[:, 0], approximate=True)
    y = (h.astype(x.dtype) * gate) @ p["out"]
    return y[:, None, :], {"conv": win[:, 1:], "h": h}
