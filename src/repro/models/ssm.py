"""Mamba-2 block: SSD (state-space duality) chunked training algorithm and
the O(1)-state decode step (arXiv:2405.21060).

Training uses the chunked SSD decomposition: within chunks of length Q the
quadratic (attention-like) form computes intra-chunk outputs; chunk-level
states are propagated by a short sequential scan (nc = S/Q steps); the
inter-chunk contribution is one more batched einsum.  All state math in
f32.  Decode carries (conv_state, ssd_state) and is O(d_inner·N) per
token, which is what makes the 524k long-context cell tractable for this
family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def ssm_init(key, cfg: ModelConfig):
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * ns                       # x, B, C go through the conv
    ks = jax.random.split(key, 4)
    s = float(1 / np.sqrt(d))
    return {
        # fused input projection: [z, xBC, dt]
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * di + 2 * ns + nh), dt) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                    dt) * float(1 / np.sqrt(cfg.conv_width)),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.asarray(
            np.log(np.linspace(1.0, 16.0, nh)), jnp.float32),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 0.1, nh))), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": jax.random.normal(ks[2], (di, d), dt) * float(1 / np.sqrt(di)),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:2 * di + 2 * ns]
    dt_raw = proj[..., 2 * di + 2 * ns:]
    return z, xbc, dt_raw


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, width K.  xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _gated_norm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            ).astype(y.dtype) * scale


def ssd_chunked(xh, dt, a, bmat, cmat, chunk: int):
    """SSD over one sequence.

    xh : (B, S, H, P) inputs per head
    dt : (B, S, H)    discretization steps (softplus applied)
    a  : (H,)         negative decay rates (A = -exp(a_log))
    bmat, cmat: (B, S, N) input/output projections (single group)
    Returns y (B, S, H, P), final_state (B, H, N, P).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # dt=0 on padding: decay exp(0)=1 and zero input -> state unchanged
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // q

    da = dt * a                                            # (B, S, H)
    xw = xh * dt[..., None]                                # dt-weighted input
    dac = da.reshape(b, nc, q, h)
    cum = jnp.cumsum(dac, axis=2)                          # (B,nc,Q,H)
    total = cum[:, :, -1]                                  # (B,nc,H)

    xc = xw.reshape(b, nc, q, h, p)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    # ---- intra-chunk (quadratic within chunk)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                        preferred_element_type=jnp.float32)  # (B,nc,Q,Q)
    li = cum[:, :, :, None, :]                             # (B,nc,Q,1,H)
    lj = cum[:, :, None, :, :]                             # (B,nc,1,Q,H)
    decay = jnp.exp(jnp.clip(li - lj, -60, 0))             # i>=j valid
    causal = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         scores, l_mat, xc.astype(jnp.float32))

    # ---- chunk states: S_c = sum_j exp(total - cum_j) B_j (dt_j x_j)^T
    state_decay = jnp.exp(jnp.clip(total[:, :, None, :] - cum, -60, 0))
    s_local = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, state_decay,
                         xc.astype(jnp.float32))           # (B,nc,H,N,P)

    # ---- inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(jnp.clip(total, -60, 0))         # (B,nc,H)

    def step(carry, inp):
        s_prev = carry                                     # (B,H,N,P)
        dec, loc = inp                                     # (B,H), (B,H,N,P)
        s_new = s_prev * dec[..., None, None] + loc
        return s_new, s_prev

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    final, s_before = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_local, 1, 0)))
    s_before = jnp.moveaxis(s_before, 0, 1)                # (B,nc,H,N,P)

    # ---- inter-chunk contribution: y_i += exp(cum_i) C_i . S_prev
    in_decay = jnp.exp(jnp.clip(cum, -60, 0))              # (B,nc,Q,H)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         cc, in_decay, s_before)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y[:, :s_orig], final


def ssm_apply_train(p, cfg: ModelConfig, x: jax.Array,
                    return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model) [, decode cache]."""
    b, s, _ = x.shape
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(b, s, nh, hp)
    bmat = xbc[..., di:di + ns].astype(jnp.float32)
    cmat = xbc[..., di + ns:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, final = ssd_chunked(xs, dt, a, bmat, cmat, cfg.ssm_chunk)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        k = p["conv_w"].shape[0]
        tail = jnp.pad(xbc_raw, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1):]
        return out, {"conv": tail, "ssd": final}
    return out


def ssm_decode_init(cfg: ModelConfig, batch: int, dtype):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ns), dtype),
        "ssd": jnp.zeros((batch, nh, ns, cfg.ssm_head_dim), jnp.float32),
    }


def ssm_apply_decode(p, cfg: ModelConfig, x, cache):
    """x: (B, 1, d_model); cache {conv (B,K-1,C), ssd (B,H,N,P)}."""
    b = x.shape[0]
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim
    proj = (x @ p["in_proj"])[:, 0]                       # (B, ...)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    # conv ring: window = [cache, xbc]
    win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"])
    new_conv = win[:, 1:]
    xs = conv[..., :di].reshape(b, nh, hp)
    bmat = conv[..., di:di + ns].astype(jnp.float32)
    cmat = conv[..., di + ns:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a)                                  # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", bmat, dt, xs.astype(jnp.float32))
    s_new = cache["ssd"] * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat, s_new)
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = _gated_norm(y, z[:, None, :], p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"conv": new_conv, "ssd": s_new}
