"""Config-driven model assembly for all assigned architectures.

Layers are organized as **period-scan**: the per-layer kind pattern
(e.g. recurrentgemma's (rglru, rglru, local), gemma3's (local×5, attn))
repeats for ``n_periods`` via one ``jax.lax.scan`` over stacked parameters
— 61-layer Kimi compiles as one scan body — with remainder layers
("head": kimi's first dense layer; "tail": pattern leftovers) unrolled.
Remat policy wraps the scan body.

Families:
  dense / moe / vlm : decoder-only LM (attention per pattern kind; MLP or
                      MoE feed-forward)
  ssm               : mamba2 blocks (no separate FFN)
  hybrid            : recurrentgemma temporal pattern + MLP every block
  encdec            : whisper — encoder stack over stubbed audio-frame
                      embeddings + decoder with cross-attention

Public entry points (all pure; see launch/ for pjit wrappers):
  init(key)                          -> params
  loss_fn(params, batch)             -> (loss, metrics)
  prefill(params, batch, cache)      -> (logits, cache)
  decode_step(params, tokens, cache, pos) -> (logits, cache)
  init_cache(batch_size, max_len)    -> cache pytree
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S

Params = Any


# ===================================================================== blocks
def _block_init(key, cfg: ModelConfig, kind: str, moe: bool,
                cross: bool = False) -> Params:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.rmsnorm_init(cfg.d_model, dt)}
    if kind in ("attn", "local"):
        p["attn"] = A.attn_init(keys[0], cfg)
    elif kind == "rglru":
        p["rglru"] = R.rglru_init(keys[0], cfg)
    elif kind == "ssm":
        p["ssm"] = S.ssm_init(keys[0], cfg)
        return p                                   # mamba2: mixer only
    else:
        raise ValueError(kind)
    if cross:
        p["normx"] = L.rmsnorm_init(cfg.d_model, dt)
        p["xattn"] = A.attn_init(keys[2], cfg, cross=True)
    p["norm2"] = L.rmsnorm_init(cfg.d_model, dt)
    if moe:
        p["moe"] = M.moe_init(keys[1], cfg)
    else:
        p["mlp"] = L.mlp_init(keys[1], cfg, cfg.d_ff)
    return p


def _block_apply_train(p, cfg: ModelConfig, kind: str, h, positions,
                       enc_out=None, enc_len=None, cache=None):
    """One block, full-sequence. Returns (h, aux, cache-or-None).

    When ``cache`` is given (prefill), the mixer's K/V (or recurrent state)
    is written into it using decode-compatible addressing."""
    aux = jnp.zeros((), jnp.float32)
    x = L.rmsnorm(p["norm1"], h, cfg.norm_eps)
    if cfg.ablate_mixer:
        # roofline diagnostic: mixer bytes are attributed by difference
        pass
    elif kind in ("attn", "local"):
        if cache is not None:
            y, (k, v) = A.attend_train(p["attn"], cfg, x, positions,
                                       kind=kind, return_kv=True)
            ck, cv = A.fill_kv_cache(cache["k"], cache["v"], k, v, kind,
                                     cfg.window)
            cache = dict(cache, k=ck, v=cv)
            h = h + y
        else:
            h = h + A.attend_train(p["attn"], cfg, x, positions, kind=kind)
    elif kind == "rglru":
        if cache is not None:
            y, st = R.rglru_apply_train(p["rglru"], cfg, x,
                                        return_state=True)
            cache = dict(cache, **st)
            h = h + y
        else:
            h = h + R.rglru_apply_train(p["rglru"], cfg, x)
    elif kind == "ssm":
        if cache is not None:
            y, st = S.ssm_apply_train(p["ssm"], cfg, x, return_state=True)
            return h + y, aux, dict(cache, **st)
        return h + S.ssm_apply_train(p["ssm"], cfg, x), aux, None
    if "xattn" in p:
        xx = L.rmsnorm(p["normx"], h, cfg.norm_eps)
        h = h + A.attend_train(p["xattn"], cfg, xx, None, kind="cross",
                               enc_out=enc_out, enc_len=enc_len)
        if cache is not None:
            xk = (enc_out @ p["xattn"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads,
                cfg.head_dim)
            xv = (enc_out @ p["xattn"]["wv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads,
                cfg.head_dim)
            cache = dict(cache, xk=xk, xv=xv)
    if "norm2" not in p:                 # mamba2 blocks have no FFN
        return h, aux, cache
    x2 = L.rmsnorm(p["norm2"], h, cfg.norm_eps)
    if "moe" in p:
        y, aux = M.moe_apply(p["moe"], cfg, x2)
        h = h + y
    else:
        h = h + L.mlp_apply(p["mlp"], x2, cfg.mlp_kind)
    return h, aux, cache


def _block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      cross: bool = False):
    dt = jnp.dtype(cfg.dtype)
    if kind in ("attn", "local"):
        c = min(cfg.window, max_len) if (kind == "local" and cfg.window)\
            else max_len
        cache = {"k": jnp.zeros((batch, c, cfg.n_kv_heads, cfg.head_dim),
                                dt),
                 "v": jnp.zeros((batch, c, cfg.n_kv_heads, cfg.head_dim),
                                dt)}
    elif kind == "rglru":
        cache = R.rglru_decode_init(cfg, batch, dt)
    elif kind == "ssm":
        cache = S.ssm_decode_init(cfg, batch, dt)
    else:
        raise ValueError(kind)
    if cross:
        cache["xk"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dt)
        cache["xv"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dt)
    return cache


def _block_apply_decode(p, cfg: ModelConfig, kind: str, h, cache, pos,
                        positions=None, enc_len=None):
    """One block, single token. Returns (h, cache)."""
    x = L.rmsnorm(p["norm1"], h, cfg.norm_eps)
    if kind in ("attn", "local"):
        y, ck, cv = A.attend_decode(p["attn"], cfg, x, cache["k"],
                                    cache["v"], pos, kind=kind,
                                    positions=positions)
        h = h + y
        cache = dict(cache, k=ck, v=cv)
    elif kind == "rglru":
        y, cc = R.rglru_apply_decode(p["rglru"], cfg, x, cache)
        h = h + y
        cache = dict(cache, **cc)
    elif kind == "ssm":
        y, cc = S.ssm_apply_decode(p["ssm"], cfg, x, cache)
        return h + y, dict(cache, **cc)
    if "xattn" in p:
        xx = L.rmsnorm(p["normx"], h, cfg.norm_eps)
        h = h + A.attend_decode_cross(p["xattn"], cfg, xx, cache["xk"],
                                      cache["xv"], enc_len)
    x2 = L.rmsnorm(p["norm2"], h, cfg.norm_eps)
    if "moe" in p:
        # drop-free capacity at decode: a one-token step must keep its experts
        y, _ = M.moe_apply(p["moe"], cfg, x2,
                           capacity_factor=float(cfg.n_experts))
        h = h + y
    else:
        h = h + L.mlp_apply(p["mlp"], x2, cfg.mlp_kind)
    return h, cache


# ==================================================================== model
@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------ structure
    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.cfg.layer_pattern

    @property
    def n_head_layers(self) -> int:
        return self.cfg.first_k_dense

    @property
    def n_scan_layers(self) -> int:
        return ((self.cfg.n_layers - self.n_head_layers)
                // len(self.pattern)) * len(self.pattern)

    @property
    def n_periods(self) -> int:
        return self.n_scan_layers // len(self.pattern)

    def tail_kinds(self) -> Tuple[str, ...]:
        n_tail = self.cfg.n_layers - self.n_head_layers - self.n_scan_layers
        return tuple(self.pattern[i % len(self.pattern)]
                     for i in range(n_tail))

    def _is_moe(self, scan_or_tail: bool) -> bool:
        return self.cfg.family == "moe"

    @property
    def _cross(self) -> bool:
        return self.cfg.family == "encdec"

    # ----------------------------------------------------------------- init
    def init(self, key) -> Params:
        cfg = self.cfg
        k_embed, k_head, k_scan, k_tail, k_enc = jax.random.split(key, 5)
        params: Dict[str, Any] = {"embed": L.embed_init(k_embed, cfg)}

        # head layers (kimi-k2 first dense layer): unrolled, dense MLP
        head = []
        for i, kk in enumerate(jax.random.split(k_head,
                                                max(self.n_head_layers, 1))):
            if i >= self.n_head_layers:
                break
            head.append(_block_init(kk, cfg, "attn", moe=False))
        params["head_blocks"] = head

        # scanned periods: stacked params per pattern position
        scan_blocks = []
        moe = self.cfg.family == "moe"
        if self.n_periods > 0:
            for pos, kind in enumerate(self.pattern):
                keys = jax.random.split(
                    jax.random.fold_in(k_scan, pos), self.n_periods)
                per = [_block_init(keys[i], cfg, kind, moe=moe,
                                   cross=self._cross)
                      for i in range(self.n_periods)]
                scan_blocks.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *per))
        params["scan_blocks"] = scan_blocks

        # tail layers: unrolled
        tail = []
        tkinds = self.tail_kinds()
        for i, kk in enumerate(jax.random.split(k_tail,
                                                max(len(tkinds), 1))):
            if i >= len(tkinds):
                break
            tail.append(_block_init(kk, cfg, tkinds[i], moe=moe,
                                    cross=self._cross))
        params["tail_blocks"] = tail

        params["final_norm"] = L.rmsnorm_init(cfg.d_model,
                                              jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            enc = []
            for kk in jax.random.split(k_enc, cfg.enc_layers):
                enc.append(_block_init(kk, cfg, "attn", moe=False))
            params["encoder"] = enc
        return params

    def init_eval(self) -> Params:
        """Abstract init (ShapeDtypeStructs) — used by the dry-run."""
        return jax.eval_shape(self.init, jax.random.key(0))

    # ------------------------------------------------------------- forward
    def _embed_inputs(self, params, batch):
        """Token + modality-stub embedding.  Returns (h, positions)."""
        cfg = self.cfg
        h = L.embed_tokens(params["embed"], batch["tokens"])
        if cfg.family == "vlm":
            # image patch embeddings (stub frontend) prepended
            h = jnp.concatenate([batch["img_embeds"].astype(h.dtype), h],
                                axis=1)
            positions = batch["positions"]            # (3, B, S) M-RoPE
        else:
            b, s = h.shape[0], h.shape[1]
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (b, s))
        return h, positions

    def _encode(self, params, batch):
        """Whisper encoder over stubbed frame embeddings (B, T, d)."""
        cfg = self.cfg
        h = batch["enc_frames"].astype(jnp.dtype(cfg.dtype))
        pos_tab = jnp.asarray(L.sinusoid_positions(h.shape[1], cfg.d_model),
                              h.dtype)
        h = h + pos_tab[None]
        for p in params["encoder"]:
            x = L.rmsnorm(p["norm1"], h, cfg.norm_eps)
            q, k, v = A._project_qkv(p["attn"], cfg, x, x, None, None)
            att = A.blockwise_attention(q, k, v, causal=False)
            b, s = x.shape[:2]
            h = h + att.reshape(b, s, cfg.q_dim) @ p["attn"]["wo"]
            x2 = L.rmsnorm(p["norm2"], h, cfg.norm_eps)
            h = h + L.mlp_apply(p["mlp"], x2, cfg.mlp_kind)
        return h

    def _remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        if self.cfg.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)

    def forward(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward.  Returns (logits_f32, aux_loss)."""
        cfg = self.cfg
        h, positions = self._embed_inputs(params, batch)
        enc_out = self._encode(params, batch) if cfg.family == "encdec" \
            else None
        enc_len = batch.get("enc_len") if cfg.family == "encdec" else None
        aux = jnp.zeros((), jnp.float32)

        for p in params["head_blocks"]:
            h, a, _ = _block_apply_train(p, cfg, "attn", h, positions)
            aux = aux + a

        pattern = self.pattern

        def period_body(carry, xs):
            h, aux = carry
            for pos, kind in enumerate(pattern):
                h, a, _ = _block_apply_train(xs[pos], cfg, kind, h,
                                             positions, enc_out=enc_out,
                                             enc_len=enc_len)
                aux = aux + a
            return (h, aux), None

        if self.n_periods > 0:
            body = self._remat(period_body)
            (h, aux), _ = jax.lax.scan(body, (h, aux),
                                       tuple(params["scan_blocks"]))

        for p, kind in zip(params["tail_blocks"], self.tail_kinds()):
            h, a, _ = _block_apply_train(p, cfg, kind, h, positions,
                                         enc_out=enc_out, enc_len=enc_len)
            aux = aux + a

        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.lm_logits(params["embed"], h, cfg.tie_embeddings,
                             out_dtype=jnp.dtype(cfg.logits_dtype),
                             true_vocab=cfg.vocab)
        return logits, aux

    # -------------------------------------------------------------- prefill
    def prefill(self, params, batch, max_len: int):
        """Process a full prompt, returning (last_logits, filled cache).

        The cache is decode-compatible: ``decode_step`` continues from
        position S.  batch needs 'tokens' (+ modality stubs)."""
        cfg = self.cfg
        h, positions = self._embed_inputs(params, batch)
        b = h.shape[0]
        enc_out = self._encode(params, batch) if cfg.family == "encdec" \
            else None
        enc_len = batch.get("enc_len") if cfg.family == "encdec" else None
        cache = self.init_cache(b, max_len)

        new_head = []
        for p, c in zip(params["head_blocks"], cache["head"]):
            h, _, c = _block_apply_train(p, cfg, "attn", h, positions,
                                         cache=c)
            new_head.append(c)

        pattern = self.pattern

        def period_body(carry, xs):
            h = carry
            blocks, caches = xs
            new_caches = []
            for pos, kind in enumerate(pattern):
                h, _, c = _block_apply_train(
                    blocks[pos], cfg, kind, h, positions, enc_out=enc_out,
                    enc_len=enc_len, cache=caches[pos])
                new_caches.append(c)
            return h, tuple(new_caches)

        new_scan = cache["scan"]
        if self.n_periods > 0:
            h, new_scan = jax.lax.scan(
                self._remat(period_body), h,
                (tuple(params["scan_blocks"]), tuple(cache["scan"])))
            new_scan = list(new_scan)

        new_tail = []
        for p, c, kind in zip(params["tail_blocks"], cache["tail"],
                              self.tail_kinds()):
            h, _, c = _block_apply_train(p, cfg, kind, h, positions,
                                         enc_out=enc_out, enc_len=enc_len,
                                         cache=c)
            new_tail.append(c)

        h = L.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
        logits = L.lm_logits(params["embed"], h, cfg.tie_embeddings,
                             out_dtype=jnp.dtype(cfg.logits_dtype),
                             true_vocab=cfg.vocab)
        cache = dict(cache, head=new_head, scan=new_scan, tail=new_tail)
        if cfg.family == "encdec":
            cache["enc_len"] = jnp.full((b,), enc_out.shape[1], jnp.int32)
        return logits[:, 0], cache

    # ---------------------------------------------------------------- loss
    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        targets = batch["targets"]
        if cfg.family == "vlm":
            # image positions carry no next-token loss
            pad = jnp.zeros(
                (targets.shape[0], batch["img_embeds"].shape[1]),
                targets.dtype)
            targets = jnp.concatenate([pad, targets], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros_like(pad, jnp.float32),
                 jnp.ones_like(batch["targets"], jnp.float32)], axis=1)
        else:
            mask = batch.get("loss_mask",
                             jnp.ones_like(targets, jnp.float32))
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None],
                                   axis=-1)[..., 0]
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux,
                      "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}

    # --------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        cache: Dict[str, Any] = {
            "head": [_block_cache_init(cfg, "attn", batch, max_len)
                     for _ in range(self.n_head_layers)],
            "tail": [_block_cache_init(cfg, k, batch, max_len,
                                       cross=self._cross)
                     for k in self.tail_kinds()],
        }
        scan = []
        for kind in self.pattern:
            per = [_block_cache_init(cfg, kind, batch, max_len,
                                     cross=self._cross)
                   for _ in range(self.n_periods)]
            scan.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per)
                        if per else [])
        cache["scan"] = scan
        if cfg.family == "encdec":
            cache["enc_len"] = jnp.zeros((batch,), jnp.int32)
        return cache

    # -------------------------------------------------------------- decode
    def decode_positions(self, pos, batch: int):
        """Positions pytree for one decode step at absolute ``pos``."""
        if self.cfg.mrope:
            p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32),
                                 (3, batch, 1))
            return p
        return None

    def decode_step(self, params, tokens, cache, pos, enc_out=None):
        """tokens (B, 1) int32; pos () int32 absolute position.

        Returns (logits (B, vocab) f32, cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        h = L.embed_tokens(params["embed"], tokens)
        positions = self.decode_positions(pos, b)
        enc_len = cache.get("enc_len") if cfg.family == "encdec" else None

        new_head = []
        for p, c in zip(params["head_blocks"], cache["head"]):
            h, c = _block_apply_decode(p, cfg, "attn", h, c, pos)
            new_head.append(c)

        pattern = self.pattern

        def period_body(carry, xs):
            h = carry
            blocks, caches = xs
            new_caches = []
            for i, kind in enumerate(pattern):
                h, c = _block_apply_decode(blocks[i], cfg, kind, h,
                                           caches[i], pos,
                                           positions=positions,
                                           enc_len=enc_len)
                new_caches.append(c)
            return h, tuple(new_caches)

        new_scan = cache["scan"]
        if self.n_periods > 0:
            h, new_scan = jax.lax.scan(
                period_body, h,
                (tuple(params["scan_blocks"]), tuple(cache["scan"])))
            new_scan = list(new_scan)

        new_tail = []
        for p, c, kind in zip(params["tail_blocks"], cache["tail"],
                              self.tail_kinds()):
            h, c = _block_apply_decode(p, cfg, kind, h, c, pos,
                                       positions=positions, enc_len=enc_len)
            new_tail.append(c)

        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.lm_logits(params["embed"], h, cfg.tie_embeddings,
                             out_dtype=jnp.dtype(cfg.logits_dtype),
                             true_vocab=cfg.vocab)
        new_cache = dict(cache, head=new_head, scan=new_scan, tail=new_tail)
        return logits[:, 0], new_cache

    def encode_for_decode(self, params, batch, cache):
        """Whisper: run the encoder, fill cross-attn K/V caches."""
        cfg = self.cfg
        enc = self._encode(params, batch)

        def fill(p, c):
            k = (enc @ p["xattn"]["wk"]).reshape(
                enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim)
            v = (enc @ p["xattn"]["wv"]).reshape(
                enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim)
            return dict(c, xk=k, xv=v)

        cache = dict(cache)
        cache["tail"] = [fill(p, c) for p, c in
                         zip(params["tail_blocks"], cache["tail"])]
        new_scan = []
        for pos in range(len(self.pattern)):
            blocks = params["scan_blocks"][pos]
            caches = cache["scan"][pos]
            filled = jax.vmap(fill)(blocks, caches)
            new_scan.append(filled)
        cache["scan"] = new_scan
        cache["enc_len"] = jnp.full((enc.shape[0],), enc.shape[1],
                                    jnp.int32)
        return cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
