import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell:
  * build the step function (train_step / prefill / serve_step),
  * ``jax.jit(step, in_shardings=…).lower(**ShapeDtypeStructs)``,
  * ``.compile()`` — SPMD partitioning for 256 (single-pod 16×16) or
    512 chips (2×16×16 multi-pod) must succeed,
  * record ``memory_analysis()`` / ``cost_analysis()`` + parsed collective
    bytes → roofline terms (launch/roofline.py),
  * write one JSON row per cell under experiments/dryrun/.

Scan-trip-count correction: XLA's cost analysis counts a while-loop body
once, so the layer-period scan under-reports FLOPs/bytes/collectives by
~n_periods.  We additionally lower a **one-period probe** (same shardings,
fwd+bwd for train) and correct:  X_true = X_top + (T-1) · X_probe.
Attention block loops are statically unrolled during dry-run lowering
(models.attention.STATIC_BLOCKS) with exact masked-block skipping, so
causal/windowed sparsity is reflected in the counts.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import shapes as shp
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attn_mod
from repro.models import model as mlib
from repro.models.model import build_model
from repro.parallel import sharding as shlib
from repro.train import optimizer as opt

attn_mod.STATIC_BLOCKS = True      # exact block-sparse cost accounting

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# FSDP needed to fit the 1T model; the 15B dense also benefits.
FSDP_ARCHS = {"kimi-k2-1t-a32b", "nemotron-4-15b"}


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def analytic_bytes_per_device(tree, shardings, mesh) -> float:
    """Sum of leaf bytes divided by each leaf's shard count."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(shardings)):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        nbytes = n * jnp.dtype(leaf.dtype).itemsize
        factor = 1
        for entry in sh.spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                factor *= mesh.shape[ax]
        total += nbytes / factor
    return total


def _compile_and_cost(fn, args, mesh):
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = dict(ca) if ca else {}
    except Exception:                                  # noqa: BLE001
        pass
    hlo = compiled.as_text()
    coll = rf.collective_bytes_from_hlo(hlo)
    return compiled, cost, coll, hlo


# ===================================================================== cells
def build_cell(arch: str, shape_name: str, mesh, fsdp: bool,
               overrides: Optional[dict] = None,
               manual_dp: bool = False, pure_dp: bool = False):
    """Returns (jitted_fn, example_args (SDS), state_trees, tokens, cfg,
    model, kind).  ``overrides`` are ModelConfig field replacements (the
    §Perf hillclimb knobs: remat, logits_dtype, moe_capacity_factor…);
    ``manual_dp`` swaps in the int8-compressed explicit-DP train step."""
    import dataclasses as _dc
    cfg = configs.get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    model = build_model(cfg)
    kind, batch = shp.input_specs(cfg, shape_name, concrete=False)
    suite = shp.SHAPES[shape_name]

    params_s = model.init_eval()
    if pure_dp:
        pshard = shlib.param_shardings_puredp(params_s, cfg, mesh)
    else:
        pshard = shlib.param_shardings(params_s, cfg, mesh, fsdp=fsdp)

    if kind == "train" and manual_dp:
        from repro.train import manual_dp as mdp
        ocfg = opt.OptConfig()
        opt_s = jax.eval_shape(opt.init, params_s)
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
        err_s = mdp.error_state_init(params_s, n_shards)
        fn, (pshard2, oshard, eshard, bshard) = mdp.build(
            model, mesh, ocfg, batch)
        args = (params_s, opt_s, err_s, batch)
        state_bytes = [(params_s, pshard2), (opt_s.mu, pshard2),
                       (opt_s.nu, pshard2), (err_s, eshard)]
        tokens = suite.seq_len * suite.global_batch
        return fn, args, state_bytes, tokens, cfg, model, kind

    if kind == "train":
        ocfg = opt.OptConfig()
        opt_s = jax.eval_shape(opt.init, params_s)
        oshard = opt.OptState(mu=pshard, nu=pshard,
                              step=shlib.replicated(mesh))
        bshard = (shlib.batch_shardings_puredp(batch, mesh) if pure_dp
                  else shlib.batch_shardings(batch, mesh))

        def step(params, opt_state, b):
            def loss_fn(p):
                loss, m = model.loss_fn(p, b)
                return loss, m
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            p2, o2, om = opt.apply_updates(params, opt_state, grads, ocfg)
            return p2, o2, dict(metrics, loss=loss, **om)

        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        args = (params_s, opt_s, batch)
        state_bytes = [(params_s, pshard), (opt_s.mu, pshard),
                       (opt_s.nu, pshard)]
        tokens = suite.seq_len * suite.global_batch
    elif kind == "prefill":
        bshard = shlib.batch_shardings(batch, mesh)

        def step(params, b):
            return model.prefill(params, b, max_len=suite.seq_len)

        fn = jax.jit(step, in_shardings=(pshard, bshard))
        args = (params_s, batch)
        state_bytes = [(params_s, pshard)]
        tokens = suite.seq_len * suite.global_batch
    else:  # decode
        cache_s = jax.eval_shape(
            lambda: model.init_cache(suite.global_batch, suite.seq_len))
        cshard = shlib.cache_shardings(
            cache_s, cfg, mesh,
            long_context=(shape_name == "long_500k"))

        def step(params, tokens_, cache, pos):
            logits, cache2 = model.decode_step(params, tokens_, cache, pos)
            return jnp.argmax(logits, -1), cache2

        fn = jax.jit(step,
                     in_shardings=(pshard,
                                   shlib.batch_shardings(batch["tokens"],
                                                         mesh),
                                   cshard, shlib.replicated(mesh)),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))
        args = (params_s, batch["tokens"], cache_s, batch["pos"])
        state_bytes = [(params_s, pshard), (cache_s, cshard)]
        tokens = suite.global_batch      # one token per sequence
    return fn, args, state_bytes, tokens, cfg, model, kind


# ===================================================================== probe
def build_probe(model, cfg, kind: str, shape_name: str, mesh, fsdp: bool,
                pure_dp: bool = False):
    """One-period probe with the cell's shardings; costs ×(T-1) correct the
    scan-once undercount.  Returns (jitted_fn, args) or None."""
    t = model.n_periods
    if t <= 1:
        return None
    suite = shp.SHAPES[shape_name]
    pattern = model.pattern
    params_s = model.init_eval()
    sliced = [jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), pb)
        for pb in params_s["scan_blocks"]]
    if pure_dp:
        pshard = tuple(shlib.param_shardings_puredp(pb, cfg, mesh)
                       for pb in sliced)
    else:
        pshard = tuple(shlib.param_shardings(pb, cfg, mesh, fsdp=fsdp)
                       for pb in sliced)
    b = suite.global_batch
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    baxes = (tuple(a for a in ("pod", "data", "model")
                   if a in mesh.shape) if pure_dp
             else shlib.batch_axes(mesh))
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    bspec = baxes if b % nb == 0 else None
    if bspec is not None and len(bspec) == 1:
        bspec = bspec[0]

    if kind in ("train", "prefill"):
        s = suite.seq_len
        h_s = jax.ShapeDtypeStruct((b, s, d), dt)
        h_sh = NamedSharding(mesh, P(bspec, None, None))
        extra_args, extra_sh = (), ()
        if cfg.family == "encdec":
            extra_args = (jax.ShapeDtypeStruct((b, cfg.enc_seq, d), dt),)
            extra_sh = (NamedSharding(mesh, P(bspec, None, None)),)

        def probe(blocks, h, *extra):
            enc_out = extra[0] if extra else None
            if cfg.mrope:
                positions = jnp.broadcast_to(
                    jnp.arange(s, dtype=jnp.int32), (3, b, s))
            else:
                positions = jnp.broadcast_to(
                    jnp.arange(s, dtype=jnp.int32), (b, s))

            def lf(blocks, h):
                aux = jnp.zeros((), jnp.float32)
                for pos, kindk in enumerate(pattern):
                    h, a, _ = mlib._block_apply_train(
                        blocks[pos], cfg, kindk, h, positions,
                        enc_out=enc_out)
                    aux = aux + a
                return h.astype(jnp.float32).sum() + aux

            if kind == "train":
                return jax.grad(lf, argnums=(0, 1))(blocks, h)
            return lf(blocks, h)

        fn = jax.jit(probe, in_shardings=(pshard, h_sh) + extra_sh)
        return fn, (tuple(sliced), h_s) + extra_args

    # decode probe
    cache_s = jax.eval_shape(
        lambda: model.init_cache(b, suite.seq_len))
    csliced = tuple(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), cb)
        for cb in cache_s["scan"])
    cshard = tuple(shlib.cache_shardings(
        cb, cfg, mesh, long_context=(shape_name == "long_500k"))
        for cb in csliced)
    h_s = jax.ShapeDtypeStruct((b, 1, d), dt)
    h_sh = NamedSharding(mesh, P(bspec, None, None))

    def probe(blocks, caches, h, pos):
        positions = model.decode_positions(pos, b)
        new_caches = []
        for i, kindk in enumerate(pattern):
            h, c = mlib._block_apply_decode(
                blocks[i], cfg, kindk, h, caches[i], pos,
                positions=positions, enc_len=None)
            new_caches.append(c)
        return h, tuple(new_caches)

    fn = jax.jit(probe,
                 in_shardings=(pshard, cshard, h_sh,
                               shlib.replicated(mesh)),
                 out_shardings=(h_sh, cshard),
                 donate_argnums=(1,))
    return fn, (tuple(sliced), csliced, h_s,
                jax.ShapeDtypeStruct((), jnp.int32))


# ===================================================================== run
def run_cell(arch: str, shape_name: str, mesh_name: str,
             fsdp: Optional[bool] = None, verbose: bool = True,
             with_probe: bool = True, overrides: Optional[dict] = None,
             variant: str = "", manual_dp: bool = False,
             pure_dp: bool = False) -> dict:
    ok, reason = shp.cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}
    multi = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    if fsdp is None:
        fsdp = arch in FSDP_ARCHS
    t0 = time.time()
    fn, args, state_bytes, tokens, cfg, model, kind = build_cell(
        arch, shape_name, mesh, fsdp, overrides, manual_dp=manual_dp,
        pure_dp=pure_dp)
    compiled, cost, coll, hlo = _compile_and_cost(fn, args, mesh)
    t_compile = time.time() - t0

    # ---- probe correction for the layer-period scan
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    coll_total = float(coll["total"])
    probe_info = None
    if with_probe and model.n_periods > 1:
        pr = build_probe(model, cfg, kind, shape_name, mesh, fsdp,
                         pure_dp=pure_dp)
        if pr is not None:
            pfn, pargs = pr
            _, pcost, pcoll, _ = _compile_and_cost(pfn, pargs, mesh)
            k = model.n_periods - 1
            pf = float(pcost.get("flops", 0.0))
            pb = float(pcost.get("bytes accessed", 0.0))
            pc = float(pcoll["total"])
            flops += k * pf
            byt += k * pb
            coll_total += k * pc
            probe_info = {"periods": model.n_periods, "probe_flops": pf,
                          "probe_bytes": pb, "probe_collective_bytes": pc}

    # ---- memory analysis (advisory on CPU backend) + analytic accounting
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
                "output_bytes": getattr(ma, "output_size_in_bytes", 0),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    ma, "generated_code_size_in_bytes", 0),
            }
    except Exception:                                  # noqa: BLE001
        pass
    analytic = sum(analytic_bytes_per_device(t, s, mesh)
                   for t, s in state_bytes)

    terms = rf.derive(arch, shape_name, mesh_name, chips, flops, byt,
                      coll_total, cfg, tokens,
                      bytes_per_device=analytic,
                      note="fsdp" if fsdp else "",
                      fwd_only=(kind != "train"))
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips, "fsdp": fsdp, "kind": kind,
        "variant": variant, "overrides": overrides or {},
        "compile_s": round(t_compile, 2),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "transcendentals")},
        "probe": probe_info,
        "memory_analysis": mem,
        "analytic_state_bytes_per_device": analytic,
        "fits_v5e_hbm_16g": bool(analytic < 16e9),
        "collectives": coll,
        "roofline": terms.row(),
        "hlo_bytes": len(hlo),
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
              f"(compile {t_compile:.1f}s, "
              f"state/device {analytic/1e9:.2f} GB, "
              f"bottleneck {terms.bottleneck}, "
              f"useful {terms.useful_ratio:.2f})")
        if mem:
            print(f"         memory_analysis: {mem}")
    return row


def cell_path(arch, shape, mesh_name, variant: str = ""):
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    return os.path.join(OUT_DIR,
                        f"{arch}__{shape}__{mesh_name}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--no-probe", action="store_true")
    # §Perf hillclimb knobs — recorded as a named variant
    ap.add_argument("--variant", default="",
                    help="tag for experiments/dryrun/<cell>__<variant>.json")
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "dots", "full"])
    ap.add_argument("--logits-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--block-q", type=int, default=None)
    ap.add_argument("--block-k", type=int, default=None)
    ap.add_argument("--pad-vocab", type=int, default=None)
    ap.add_argument("--scores-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--manual-dp-int8", action="store_true",
                    help="explicit shard_map DP with int8 EF all-reduce")
    ap.add_argument("--ablate-mixer", action="store_true",
                    help="diagnostic: skip attention/ssm mixers")
    ap.add_argument("--pure-dp", action="store_true",
                    help="no-TP layout: batch over both axes + ZeRO-3")
    args = ap.parse_args()

    overrides = {}
    if args.remat is not None:
        overrides["remat"] = args.remat
    if args.logits_dtype is not None:
        overrides["logits_dtype"] = args.logits_dtype
    if args.capacity_factor is not None:
        overrides["moe_capacity_factor"] = args.capacity_factor
    if args.block_q is not None:
        overrides["attn_block_q"] = args.block_q
    if args.block_k is not None:
        overrides["attn_block_k"] = args.block_k
    if args.pad_vocab is not None:
        overrides["pad_vocab_multiple"] = args.pad_vocab
    if args.scores_dtype is not None:
        overrides["attn_scores_dtype"] = args.scores_dtype
    if args.ablate_mixer:
        overrides["ablate_mixer"] = True

    archs = configs.ARCHS if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    fsdp = None if args.fsdp is None else (args.fsdp == "on")

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                path = cell_path(arch, shape, mesh_name, args.variant)
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] cached: {path}")
                    continue
                try:
                    row = run_cell(arch, shape, mesh_name, fsdp=fsdp,
                                   with_probe=not args.no_probe,
                                   overrides=overrides or None,
                                   variant=args.variant,
                                   manual_dp=args.manual_dp_int8,
                                   pure_dp=args.pure_dp)
                except Exception as e:                 # noqa: BLE001
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape,
                           "mesh": mesh_name, "status": "FAILED",
                           "variant": args.variant,
                           "error": str(e)[-2000:]}
                    failures.append((arch, shape, mesh_name))
                with open(path, "w") as f:
                    json.dump(row, f, indent=1)
    if failures:
        print("FAILED cells:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
