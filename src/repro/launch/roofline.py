"""Roofline-term derivation from a compiled dry-run artifact.

Per (arch × shape × mesh):

  compute term    = HLO_FLOPs            / (chips × 197e12 FLOP/s bf16)
  memory term     = HLO_bytes_accessed   / (chips × 819e9  B/s HBM)
  collective term = collective_bytes     / (chips × 50e9   B/s ICI link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective
bytes are *not* in cost_analysis: we parse the post-SPMD HLO text and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  Shapes in the partitioned module are
per-device, so the summed per-device collective bytes divided by the link
bandwidth directly gives seconds-per-device (the ×chips in numerator and
denominator cancel).

Also derives MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPS (remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (given)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape like  bf16[16,512,128]{2,1,0}  or  f32[] or tuple (...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum per-device operand bytes of collective ops, by op kind.

    Matches lines like:
      %ag = bf16[2048,512] all-gather(bf16[128,512] %x), ...
    counting the *output* shape (bytes that cross the interconnect are
    bounded by max(in, out); output is the conservative choice for
    all-gather, input for reduce-scatter — we take max of both sides).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-shape = op-name(args)
        for kind in _COLLECTIVES:
            # match ` = <shape> kind(` and `kind-start(` variants
            m = re.search(r"=\s+(.+?)\s+" + kind + r"(?:-start)?\(", s)
            if m:
                lhs_bytes = _shape_bytes(m.group(1))
                args = s[m.end():]
                rhs_bytes = _shape_bytes(args)
                out[kind] += max(lhs_bytes, rhs_bytes)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float           # per-device sum
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_ratio: float
    bottleneck: str
    bytes_per_device: Optional[float] = None
    note: str = ""

    def row(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, tokens: int, fwd_only: bool = False
                ) -> float:
    """6·N·D (train: fwd 2ND + bwd 4ND) or 2·N·D (prefill/decode, forward
    only), N = active params (MoE: routed top-k + shared only)."""
    n_active = cfg.param_count(active_only=True)
    return (2.0 if fwd_only else 6.0) * n_active * tokens


def derive(arch: str, shape: str, mesh_name: str, chips: int,
           flops: float, byt: float, collective_bytes: float,
           cfg: ModelConfig, tokens: int,
           bytes_per_device: Optional[float] = None,
           note: str = "", fwd_only: bool = False) -> RooflineTerms:
    # cost_analysis on the partitioned module reports per-device numbers;
    # per-device seconds = per-device work / per-chip rate.
    compute_s = flops / PEAK_FLOPS
    memory_s = byt / HBM_BW
    collective_s = collective_bytes / ICI_BW
    mf = model_flops(cfg, tokens, fwd_only=fwd_only)
    useful = mf / max(flops * chips, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byt, collective_bytes=collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, useful_ratio=useful, bottleneck=bottleneck,
        bytes_per_device=bytes_per_device, note=note)


def to_markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | MODEL_FLOPS/HLO | note |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.3f} | {r.get('note','')} |")
    return "\n".join(lines)
