"""Serving driver: prefill a batch of prompts, then greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Uses the same model/caches the dry-run lowers for the decode cells; on a
real pod the params/caches carry the shardings of parallel/sharding.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import shapes as sh
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.gen + 8)

    rng = np.random.default_rng(args.seed)
    batch = sh.prefill_batch_specs(cfg, args.prompt_len, args.batch,
                                   concrete=True, rng=rng)
    t0 = time.perf_counter()
    state = engine.prefill(batch)
    t_prefill = time.perf_counter() - t0
    toks, state = engine.generate(state, steps=args.gen)
    t_decode = time.perf_counter() - t0 - t_prefill
    out = np.asarray(toks)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode/args.gen*1e3:.2f}ms/tok")
    print(f"[serve] generated tokens[0] = {out[0].tolist()}")
    return {"tokens": out, "prefill_s": t_prefill,
            "decode_s_per_tok": t_decode / args.gen}


if __name__ == "__main__":
    main()
