"""Fault-tolerant execution wrapper + elastic-rescale helpers.

``run_with_restarts`` is the supervisor a real deployment runs per job:
any exception (preemption, device loss, NaN guard) triggers a bounded
restart; state comes back from the last atomic checkpoint.  Combined with
train/checkpoint.py's mesh-independent restore, a restart may come up on
a *different* device count (elastic rescale): the caller rebuilds mesh +
shardings and `restore` re-places every leaf.

Straggler mitigation at 1000+ nodes: the per-step watchdog in
train/trainer.py flags slow steps; on a real multi-host job the
documented policy is (1) flagging hosts that straggle persistently,
(2) checkpoint-and-exclude via this supervisor — restart on the reduced
(elastic) mesh.  Both mechanisms are exercised by tests/test_faults.py.
"""
from __future__ import annotations

import dataclasses
import time
import traceback
from typing import Any, Callable, Optional


@dataclasses.dataclass
class RestartReport:
    restarts: int
    succeeded: bool
    errors: list


def run_with_restarts(make_state: Callable[[], Any],
                      run: Callable[[Any, int], Any],
                      max_restarts: int = 3,
                      backoff_s: float = 0.0) -> tuple:
    """Supervisor loop.

    make_state(): build fresh (or checkpoint-restored) state; called before
    every attempt so a restart reloads from the last checkpoint.
    run(state, attempt): runs the job; raising triggers a restart.
    """
    errors = []
    for attempt in range(max_restarts + 1):
        state = make_state()
        try:
            result = run(state, attempt)
            return result, RestartReport(attempt, True, errors)
        except Exception as e:                    # noqa: BLE001
            errors.append(
                "".join(traceback.format_exception_only(type(e), e)).strip())
            if backoff_s:
                time.sleep(backoff_s * (2 ** attempt))
    return None, RestartReport(max_restarts, False, errors)


class NaNGuard:
    """Raises on non-finite loss — turns silent divergence into a restart
    (the checkpoint predates the blow-up)."""

    def __init__(self, patience: int = 1):
        self.patience = patience
        self.strikes = 0

    def check(self, loss: float):
        import math
        if not math.isfinite(loss):
            self.strikes += 1
            if self.strikes >= self.patience:
                raise FloatingPointError(f"non-finite loss {loss}")
        else:
            self.strikes = 0
