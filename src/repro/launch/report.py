"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

Recomputes roofline terms from the *raw* stored measurements (top-level
cost_analysis + one-period probe + collective byte parse), so formula
refinements never require recompiling the 66-cell sweep.

    PYTHONPATH=src python -m repro.launch.report [--markdown out.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List

from repro import configs
from repro.configs import shapes as shp
from repro.launch import roofline as rf
from repro.launch.dryrun import OUT_DIR


def load_rows() -> List[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        rows.append(json.load(open(p)))
    return rows


def recompute(row: dict) -> dict:
    """Fresh roofline terms from raw stored numbers."""
    if row.get("status") != "ok":
        return row
    cfg = configs.get_config(row["arch"])
    if row.get("overrides"):
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **row["overrides"])
    suite = shp.SHAPES[row["shape"]]
    kind = row.get("kind", suite.kind)
    ca = row.get("cost_analysis", {})
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    coll = float(row.get("collectives", {}).get("total", 0.0))
    probe = row.get("probe")
    if probe:
        k = probe["periods"] - 1
        flops += k * probe["probe_flops"]
        byt += k * probe["probe_bytes"]
        coll += k * probe["probe_collective_bytes"]
    tokens = (suite.seq_len * suite.global_batch
              if kind in ("train", "prefill") else suite.global_batch)
    terms = rf.derive(row["arch"], row["shape"], row["mesh"],
                      row["chips"], flops, byt, coll, cfg, tokens,
                      bytes_per_device=row.get(
                          "analytic_state_bytes_per_device"),
                      note=("fsdp" if row.get("fsdp") else ""),
                      fwd_only=(kind != "train"))
    out = dict(row)
    out["roofline"] = terms.row()
    return out


def dominant_time(r: dict) -> float:
    t = r["roofline"]
    return max(t["compute_s"], t["memory_s"], t["collective_s"])


def roofline_fraction(r: dict) -> float:
    """compute term / dominant term — how close the cell is to being
    compute-(roof)-bound; 1.0 = at the compute roofline."""
    t = r["roofline"]
    return t["compute_s"] / max(dominant_time(r), 1e-30)


def markdown(rows: List[dict]) -> str:
    variants = [r for r in rows if r.get("variant")]
    rows = [r for r in rows if not r.get("variant")]
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    failed = [r for r in rows if r.get("status") not in ("ok", "skipped")]
    lines = []
    lines.append("### Dry-run matrix\n")
    lines.append(f"OK: {len(ok)}  skipped (documented): {len(skipped)}  "
                 f"failed: {len(failed)}\n")
    lines.append("| arch | shape | mesh | chips | kind | compile s | "
                 "state GB/dev | fits 16G | note |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
            f"| {r.get('kind','')} | {r.get('compile_s','')} "
            f"| {r['analytic_state_bytes_per_device']/1e9:.2f} "
            f"| {'yes' if r['fits_v5e_hbm_16g'] else 'NO'} "
            f"| {r['roofline'].get('note','')} |")
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                     f"| — | — | — | SKIP: {r['reason'][:60]} |")
    lines.append("\n### Roofline terms (single-pod)\n")
    lines.append("| arch | shape | compute s | memory s | collective s | "
                 "bottleneck | roofline frac | MODEL/HLO | "
                 "what moves the dominant term |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        if r["mesh"] != "pod":
            continue
        t = r["roofline"]
        frac = roofline_fraction(r)
        hint = {
            "compute": "already compute-bound: fuse/skip redundant flops",
            "memory": "cut HBM traffic: bf16 logits, fused CE, remat tune",
            "collective": "reshard / overlap collectives with compute",
        }[t["bottleneck"]]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} "
            f"| {t['memory_s']:.3e} | {t['collective_s']:.3e} "
            f"| {t['bottleneck']} | {frac:.3f} "
            f"| {t['useful_ratio']:.3f} | {hint} |")
    if variants:
        lines.append("\n### Perf-iteration variants\n")
        lines.append("| arch | shape | mesh | variant | compute s | "
                     "memory s | collective s | bottleneck |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for r in variants:
            if r.get("status") != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                             f"| {r['variant']} | FAILED | | | |")
                continue
            t = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['variant']} | {t['compute_s']:.3e} "
                f"| {t['memory_s']:.3e} | {t['collective_s']:.3e} "
                f"| {t['bottleneck']} |")
    if failed:
        lines.append("\n### FAILED cells\n")
        for r in failed:
            lines.append(f"- {r['arch']} × {r['shape']} × {r['mesh']}: "
                         f"{r.get('error','')[:200]}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", default=None)
    args = ap.parse_args()
    rows = [recompute(r) for r in load_rows()]
    md = markdown(rows)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(md)
        print(f"wrote {args.markdown}")
    else:
        print(md)


if __name__ == "__main__":
    main()
