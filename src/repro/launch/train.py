"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --smoke --steps 100 --batch 8 --seq 128 --spin-ingest

Wires together: config registry → model → (optional) mesh + shardings →
AdamW → packetized SLMP/DDT data pipeline with SpinIngest (the paper's
offloaded datatype processing) double-buffered against the train step →
atomic checkpoints → fault supervisor with bounded restarts.

``--smoke`` selects the reduced same-family config (CPU-runnable);
omitting it uses the full assigned architecture (real-cluster scale; on
this host only the dry-run path makes sense for those — see
launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import overlap as ovl
from repro.launch import faults
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train import data as datalib
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    ap.add_argument("--spin-ingest", action="store_true",
                    help="feed training through the packetized SLMP/DDT "
                         "sPIN pipeline (paper §V-C) with overlap")
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = build_model(cfg)
    print(f"[train] arch={cfg.name} params~{cfg.param_count():,} "
          f"steps={args.steps} batch={args.batch} seq={args.seq} "
          f"spin_ingest={args.spin_ingest}")

    ocfg = opt.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                         total_steps=args.steps)
    tcfg = TrainerConfig(steps=args.steps, microbatches=args.microbatches,
                         log_every=max(args.steps // 20, 1),
                         ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, donate=False)

    def make_state():
        params = model.init(jax.random.key(args.seed))
        return params, opt.init(params)

    def run(state, attempt):
        params, ost = state
        trainer = Trainer(model, ocfg, tcfg)
        if args.spin_ingest:
            pipe = datalib.PacketizedPipeline(
                vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                seed=args.seed)
            ingest = datalib.SpinIngest(pipe)
            feeds = datalib.prefetch_iterator(pipe, args.steps)
            # double-buffered: ingest t+1 overlaps train step t
            step_fn = trainer.build_step()
            t_mm = t_poll = 0.0
            params_, ost_ = params, ost
            batch = ingest(next(feeds))
            hist = []
            for i, feed in enumerate(feeds):
                params_, ost_, metrics = step_fn(params_, ost_, batch)
                nxt = ingest(feed)                     # overlaps step
                t0 = time.perf_counter()
                jax.block_until_ready(metrics["loss"])
                t1 = time.perf_counter()
                jax.block_until_ready(nxt)
                t2 = time.perf_counter()
                t_mm += t1 - t0
                t_poll += t2 - t1
                batch = nxt
                if (i + 1) % tcfg.log_every == 0:
                    hist.append({"step": i + 1,
                                 "loss": float(metrics["loss"])})
                    print(f"  step {i+1:5d} loss "
                          f"{float(metrics['loss']):.4f}")
                if tcfg.ckpt_every and (i + 1) % tcfg.ckpt_every == 0:
                    ckpt.save(tcfg.ckpt_dir, i + 1, (params_, ost_))
            r = t_mm / max(t_mm + t_poll, 1e-12)
            print(f"[train] overlap ratio R = {r:.4f} "
                  f"(t_train={t_mm:.2f}s t_poll={t_poll:.2f}s)")
            return {"history": hist, "overlap_ratio": r}
        else:
            corpus = datalib.SyntheticCorpus(cfg.vocab, seed=args.seed)

            def batches():
                import jax.numpy as jnp
                for i in range(args.steps):
                    toks = corpus.batch(i, args.batch, args.seq)
                    yield {"tokens": jnp.asarray(toks[:, :-1]),
                           "targets": jnp.asarray(toks[:, 1:])}

            p2, o2, hist = trainer.fit(params, ost, batches(),
                                       resume=attempt > 0)
            for h in hist[-3:]:
                print(f"  step {h['step']:5d} loss {h['loss']:.4f}")
            return {"history": hist,
                    "stragglers": trainer.straggler_events}

    result, report = faults.run_with_restarts(
        make_state, run, max_restarts=args.max_restarts)
    if not report.succeeded:
        raise SystemExit(f"training failed after {report.restarts} "
                         f"restarts: {report.errors}")
    print(f"[train] done (restarts={report.restarts})")
    return result


if __name__ == "__main__":
    main()
