"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod : (2, 16, 16) = 512 chips, axes (pod, data, model) — ``pod`` is
the outer data-parallel axis (DCN between pods; ICI within).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
