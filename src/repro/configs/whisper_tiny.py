"""whisper-tiny [audio]: 4L(enc)+4L(dec) d_model=384 6H (kv=6) d_ff=1536
vocab=51865 — encoder-decoder; conv frontend STUB.  [arXiv:2212.04356]

``input_specs`` provides precomputed audio-frame embeddings
(B, 1500, 384) — the output of the stubbed conv1d×2 frontend at 50 Hz over
30 s of audio.  The encoder is bidirectional with sinusoidal positions;
the decoder is causal with cross-attention every layer (decoder positions
use RoPE here — a documented substitution for Whisper's learned absolute
embeddings, irrelevant to the systems behaviour being measured).
MLP kind is plain GELU (no gating), as in the original.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51865,
    enc_layers=4, enc_seq=1500,
    mlp_kind="gelu", rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, d_model=48, n_heads=2, n_kv_heads=2, head_dim=24,
        d_ff=96, vocab=256,
        enc_layers=2, enc_seq=32,
        mlp_kind="gelu", remat="none",
    )
