"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8 — trillion-parameter MoE.  [arXiv:2501.kimi2]

Interpretation of the assigned "d_ff=2048": the routed-expert intermediate
size (matches the public K2 config ``moe_intermediate_size: 2048``).  Per
the K2 paper: the first layer is dense (``first_k_dense_replace: 1``) with
dense intermediate 18432, one shared expert of 2048, 60 MoE layers...
here 61 layers = 1 dense + 60 MoE.  384 experts divide the 16-way model
axis exactly (24 experts/shard).  Assignment specifies GQA kv=8 (the real
model uses MLA; we follow the assignment).

Scale note: ~1.03e12 params — needs FSDP sharding over the data axis to
fit; see EXPERIMENTS.md §Dry-run for the per-device memory accounting.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=18432, vocab=163840,
    n_experts=384, top_k=8, d_ff_expert=2048,
    n_shared_experts=1, d_ff_shared=2048,
    first_k_dense=1,
    mlp_kind="swiglu", rope_theta=50_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab=256,
        n_experts=8, top_k=2, d_ff_expert=32,
        n_shared_experts=1, d_ff_shared=32,
        first_k_dense=1,
        mlp_kind="swiglu", remat="none", moe_capacity_factor=8.0,
    )
