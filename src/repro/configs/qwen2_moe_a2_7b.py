"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

d_ff=1408 is the routed-expert intermediate size (HF
``moe_intermediate_size``); the 4 shared experts of 1408 each give the HF
``shared_expert_intermediate_size`` of 5632.  60 experts are zero-padded
to 64 for 16-way expert parallelism (router scores real experts only).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=5632, vocab=151936,
    n_experts=60, top_k=4, d_ff_expert=1408,
    n_shared_experts=4, d_ff_shared=1408,
    mlp_kind="swiglu", rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        n_experts=6, top_k=2, d_ff_expert=32,
        n_shared_experts=2, d_ff_shared=32,
        mlp_kind="swiglu", remat="none", moe_capacity_factor=8.0,
    )
