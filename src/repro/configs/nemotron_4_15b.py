"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU MLP (no gate).  [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000,
    mlp_kind="squared_relu", rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=256, vocab=256,
        mlp_kind="squared_relu", remat="none",
    )
