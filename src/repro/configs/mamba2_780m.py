"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060]

d_inner = 2·d_model = 3072, head_dim 64 → 48 SSD heads; conv width 4;
chunked SSD with chunk 128 for training; O(1) state decode → runs the
long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280,
    layer_pattern=("ssm",),
    ssm_state=128, d_inner=3072, ssm_heads=48, ssm_head_dim=64,
    conv_width=4, ssm_chunk=128,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=1, n_kv_heads=1, head_dim=16,
        d_ff=0, vocab=256,
        layer_pattern=("ssm",),
        ssm_state=16, d_inner=128, ssm_heads=8, ssm_head_dim=16,
        conv_width=4, ssm_chunk=8, remat="none",
    )
