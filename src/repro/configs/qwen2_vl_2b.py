"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Backbone only; the vision frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, img_tokens, d_model) and the (3, B, S)
M-RoPE position ids (temporal / height / width components).
"""
from repro.configs.base import ModelConfig

IMG_TOKENS = 1024      # stubbed patch-embedding tokens per sample

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936,
    qkv_bias=True, mrope=True, rope_theta=1_000_000.0,
    img_tokens=IMG_TOKENS, mlp_kind="swiglu",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        qkv_bias=True, mrope=True, img_tokens=8, mlp_kind="swiglu",
        remat="none",
    )
