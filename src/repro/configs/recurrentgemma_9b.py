"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, 1:2 attn:recurrent pattern.
[arXiv:2402.19427]

Pattern period (rglru, rglru, local): 38 layers = 12 periods + 2 tail
rglru layers.  Sliding window 2048, lru_width = d_model = 4096, GeGLU MLP
in every block.  O(1) recurrent state + windowed attention → runs the
long_500k decode cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    layer_pattern=("rglru", "rglru", "local"), window=2048,
    lru_width=4096, mlp_kind="geglu", rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab=256,
        layer_pattern=("rglru", "rglru", "local"), window=16,
        lru_width=64, mlp_kind="geglu", remat="none",
    )
