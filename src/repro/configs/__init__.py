"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

One module per assigned architecture; each exposes ``CONFIG`` (the exact
assigned full-size configuration) and ``smoke()`` (a reduced same-family
config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

ARCHS: List[str] = [
    "qwen2-moe-a2.7b",
    "kimi-k2-1t-a32b",
    "whisper-tiny",
    "recurrentgemma-9b",
    "mamba2-780m",
    "qwen3-1.7b",
    "nemotron-4-15b",
    "qwen2-7b",
    "gemma3-1b",
    "qwen2-vl-2b",
]

_MODULES: Dict[str, str] = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-780m": "mamba2_780m",
    "qwen3-1.7b": "qwen3_1_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2-7b": "qwen2_7b",
    "gemma3-1b": "gemma3_1b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke()
