"""Assigned input-shape suites and ``input_specs`` stand-ins.

Four shapes per architecture (40 cells):

  train_4k    : seq 4096,   global_batch 256  -> train_step
  prefill_32k : seq 32768,  global_batch 32   -> prefill (serve)
  decode_32k  : seq 32768,  global_batch 128  -> serve_step (1 new token,
                                                 KV cache of 32768)
  long_500k   : seq 524288, global_batch 1    -> serve_step; requires
                sub-quadratic attention — runs only for SSM / hybrid /
                mostly-local archs, skipped (and recorded) otherwise.

``input_specs`` returns weak-type-correct ``jax.ShapeDtypeStruct``
stand-ins (or concrete arrays for smoke tests) for every model input —
no device allocation during the dry-run.  Modality frontends are stubs:
whisper gets precomputed frame embeddings, qwen2-vl gets patch embeddings
and M-RoPE position ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524288, 1, "decode"),
}

# Archs whose attention cost is sub-quadratic / O(1)-state at decode time.
LONG_CONTEXT_ARCHS = {"mamba2-780m", "recurrentgemma-9b", "gemma3-1b"}


def cell_supported(arch: str, shape_name: str) -> Tuple[bool, str]:
    """Is this (arch × shape) cell in contract?  Returns (ok, reason)."""
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, ("pure full-attention arch: 524k decode requires "
                       "sub-quadratic attention (DESIGN.md skip list)")
    return True, ""


def _arr(shape, dtype, concrete: bool, rng: Optional[np.random.Generator],
         low=0, high=2):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    rng = rng or np.random.default_rng(0)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.integers(low, high, size=shape), dtype)
    return jnp.asarray(rng.normal(size=shape) * 0.02, dtype)


def train_batch_specs(cfg: ModelConfig, seq: int, batch: int,
                      concrete: bool = False,
                      rng: Optional[np.random.Generator] = None
                      ) -> Dict[str, Any]:
    """Inputs for train_step / prefill.  seq is the *total* sequence."""
    dt = jnp.dtype(cfg.dtype)
    v = cfg.vocab
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        img = min(cfg.img_tokens, seq // 2)
        text = seq - img
        out["tokens"] = _arr((batch, text), jnp.int32, concrete, rng,
                             high=v)
        out["targets"] = _arr((batch, text), jnp.int32, concrete, rng,
                              high=v)
        out["img_embeds"] = _arr((batch, img, cfg.d_model), dt, concrete,
                                 rng)
        if concrete:
            # stub M-RoPE ids: all three components advance with position
            # (text behaviour; image rows/cols would diverge in h/w comps)
            pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                   (3, batch, seq))
            out["positions"] = pos
        else:
            out["positions"] = _arr((3, batch, seq), jnp.int32, concrete,
                                    rng, high=seq)
    elif cfg.family == "encdec":
        out["tokens"] = _arr((batch, seq), jnp.int32, concrete, rng, high=v)
        out["targets"] = _arr((batch, seq), jnp.int32, concrete, rng,
                              high=v)
        out["enc_frames"] = _arr((batch, cfg.enc_seq, cfg.d_model), dt,
                                 concrete, rng)
        out["enc_len"] = _arr((batch,), jnp.int32, concrete, rng,
                              low=cfg.enc_seq, high=cfg.enc_seq + 1)
    else:
        out["tokens"] = _arr((batch, seq), jnp.int32, concrete, rng, high=v)
        out["targets"] = _arr((batch, seq), jnp.int32, concrete, rng,
                              high=v)
    return out


def prefill_batch_specs(cfg: ModelConfig, seq: int, batch: int,
                        concrete: bool = False,
                        rng: Optional[np.random.Generator] = None):
    b = train_batch_specs(cfg, seq, batch, concrete, rng)
    b.pop("targets", None)
    return b


def decode_specs(cfg: ModelConfig, seq: int, batch: int,
                 concrete: bool = False,
                 rng: Optional[np.random.Generator] = None
                 ) -> Dict[str, Any]:
    """Inputs for serve_step: one new token against a cache of ``seq``."""
    return {
        "tokens": _arr((batch, 1), jnp.int32, concrete, rng,
                       high=cfg.vocab),
        "pos": (jnp.asarray(seq - 1, jnp.int32) if concrete
                else jax.ShapeDtypeStruct((), jnp.int32)),
    }


def input_specs(cfg: ModelConfig, shape_name: str, concrete: bool = False,
                rng: Optional[np.random.Generator] = None):
    """(step_kind, batch-pytree) for one assigned cell."""
    s = SHAPES[shape_name]
    if s.kind == "train":
        return "train", train_batch_specs(cfg, s.seq_len, s.global_batch,
                                          concrete, rng)
    if s.kind == "prefill":
        return "prefill", prefill_batch_specs(cfg, s.seq_len,
                                              s.global_batch, concrete, rng)
    return "decode", decode_specs(cfg, s.seq_len, s.global_batch,
                                  concrete, rng)
