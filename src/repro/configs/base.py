"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # --- attention flavour ---
    qk_norm: bool = False         # qwen3
    qkv_bias: bool = False        # qwen2 / qwen2-vl
    rope_theta: float = 10_000.0
    mrope: bool = False           # qwen2-vl M-RoPE (3-component positions)
    window: int = 0               # sliding-window size for 'local' layers
    pos_kind: str = "rope"        # rope | sinusoid (whisper encoder/decoder)

    # --- block pattern: kinds repeated to n_layers ---
    # kinds: attn (global), local (sliding window), rglru, ssm
    layer_pattern: Tuple[str, ...] = ("attn",)

    # --- mlp ---
    mlp_kind: str = "swiglu"      # swiglu | geglu | squared_relu | gelu

    # --- moe (family == moe) ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    first_k_dense: int = 0        # kimi-k2: first layer dense
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25

    # --- ssm (mamba2) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0
    conv_width: int = 4
    ssm_chunk: int = 128

    # --- rg-lru (recurrentgemma) ---
    lru_width: int = 0

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 1500           # precomputed audio-frame embeddings (stub)

    # --- vlm (qwen2-vl) ---
    img_tokens: int = 0           # precomputed patch embeddings (stub)

    # --- numerics / misc ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # remat policy for the layer scan: none | dots | full
    remat: str = "dots"
    # dtype the (B, S, vocab) logits are materialized in; CE math is f32
    # either way (conversions fuse into the reductions).  "bfloat16"
    # halves the largest activation tensor's HBM traffic (§Perf H1).
    logits_dtype: str = "float32"
    # streaming-attention block sizes: larger block_q => fewer passes over
    # the (replicated-KV) cache => less HBM traffic (§Perf H2)
    attn_block_q: int = 512
    attn_block_k: int = 512
    # dtype attention scores/probabilities are materialized in between the
    # QK^T and PV einsums (softmax stats stay f32).  "bfloat16" halves the
    # dominant S²-shaped HBM traffic of the HLO attention — the same trick
    # a fused flash kernel plays inside VMEM (§Perf H4).
    attn_scores_dtype: str = "float32"
    # pad the vocab dim to a multiple (0 = off) so embeddings/logits shard
    # over the model axis even for awkward vocab sizes (§Perf H3; padded
    # logit lanes are masked to -inf in lm_logits)
    pad_vocab_multiple: int = 0
    # diagnostic: skip the sequence mixer (attention/ssm/rglru) entirely —
    # used by the roofline ablation to attribute HBM bytes to attention
    # (never a training configuration)
    ablate_mixer: bool = False

    # ---------------------------------------------------------- helpers
    @property
    def padded_vocab(self) -> int:
        if self.pad_vocab_multiple <= 1:
            return self.vocab
        m = self.pad_vocab_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def pattern_layers(self) -> Tuple[str, ...]:
        """Per-layer kind list of length n_layers (pattern tiled)."""
        p = self.layer_pattern
        reps = (self.n_layers + len(p) - 1) // len(p)
        return tuple((p * reps)[: self.n_layers])

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def n_tail(self) -> int:
        """Layers not covered by full periods (unrolled)."""
        return self.n_layers - self.n_periods * len(self.layer_pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def moe_layer(self, layer_idx: int) -> bool:
        return self.family == "moe" and layer_idx >= self.first_k_dense

    # Parameter count (analytic; used by roofline MODEL_FLOPS and memory
    # accounting).  Counts all trainable params.
    def param_count(self, active_only: bool = False) -> int:
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb + d                              # final norm
        for i, kind in enumerate(self.pattern_layers):
            total += 2 * d                           # two block norms
            if kind in ("attn", "local"):
                total += d * self.q_dim + 2 * d * self.kv_dim \
                    + self.q_dim * d
                if self.qkv_bias:
                    total += self.q_dim + 2 * self.kv_dim
                if self.qk_norm:
                    total += 2 * self.head_dim
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * d + 4 * w \
                    + 2 * w * (self.conv_width)      # temporal conv
            elif kind == "ssm":
                di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * ns + nh) \
                    + self.conv_width * (di + 2 * ns) + 2 * nh + di \
                    + di * d
            # mlp / moe
            if kind == "ssm":
                pass                                  # mamba2: no extra mlp
            elif self.moe_layer(i):
                e = self.n_experts
                if not active_only:
                    total += 3 * d * self.d_ff_expert * e
                else:
                    total += 3 * d * self.d_ff_expert * self.top_k
                total += d * e                        # router
                total += 3 * d * self.d_ff_shared * self.n_shared_experts
                if self.first_k_dense and i < self.first_k_dense:
                    pass
            else:
                ff = self.d_ff
                mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                total += mult * d * ff
        # encoder stack (whisper): enc_layers of attn + mlp
        mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        for _ in range(self.enc_layers):
            total += 2 * d + d * self.q_dim + 2 * d * self.kv_dim \
                + self.q_dim * d + mult * d * self.d_ff
        if self.family == "encdec":
            # decoder cross-attention per layer
            total += self.n_layers * (d * self.q_dim + 2 * d * self.kv_dim
                                      + self.q_dim * d + d)
        return int(total)
