"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936,
    qk_norm=True, rope_theta=1_000_000.0, mlp_kind="swiglu",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        qk_norm=True, rope_theta=1_000_000.0, mlp_kind="swiglu",
        remat="none",
    )
