"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global interleaving, 128k context.
[hf:google/gemma-3-1b-pt]

Pattern period = (local ×5, attn); 26 layers = 4 full periods + 2 tail
local layers.  Sliding window 512 (gemma3-1b HF config).  GeGLU MLP,
head_dim 256 (q_dim 1024 ≠ d_model, as in the real config).
Runs the long_500k cell: only the 4 global layers keep a full-length KV
cache (sequence-sharded); local layers cache one window.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    window=512, layer_pattern=("local", "local", "local", "local",
                               "local", "attn"),
    mlp_kind="geglu", rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        n_layers=8, d_model=48, n_heads=2, n_kv_heads=1, head_dim=24,
        d_ff=96, vocab=256,
        window=16, layer_pattern=("local", "local", "attn"),
        mlp_kind="geglu", remat="none",
    )
