"""Serving engine: prefill + greedy decode over the model zoo's caches.

Jitted once per (model, batch, max_len); decode donates the cache (in-place
on device).  This is the single-host form of the engine the decode-cell
dry-runs lower for 256/512 chips (cache shardings from
parallel/sharding.py, incl. sequence-sharded long-context caches).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model


@dataclasses.dataclass
class ServeState:
    cache: Any
    last_tokens: jax.Array      # (B, 1)
    pos: jax.Array              # () int32 — next position to write


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))

        def _decode(p, tokens, cache, pos):
            logits, cache2 = model.decode_step(p, tokens, cache, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt[:, None], cache2

        self._decode = jax.jit(_decode, donate_argnums=(2,))

    def prefill(self, batch: Dict[str, Any]) -> ServeState:
        logits, cache = self._prefill(self.params, batch)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.family == "vlm":
            prompt_len += batch["img_embeds"].shape[1]
        return ServeState(cache=cache, last_tokens=first,
                          pos=jnp.asarray(prompt_len, jnp.int32))

    def step(self, state: ServeState) -> Tuple[jax.Array, ServeState]:
        nxt, cache = self._decode(self.params, state.last_tokens,
                                  state.cache, state.pos)
        return nxt, ServeState(cache=cache, last_tokens=nxt,
                               pos=state.pos + 1)

    def generate(self, state: ServeState, steps: int):
        toks = [state.last_tokens]
        for _ in range(steps - 1):
            nxt, state = self.step(state)
            toks.append(nxt)
        return jnp.concatenate(toks, axis=1), state
