"""Analytic FPsPIN hardware timing model (paper Tables I–II, Fig 7).

The FPGA artifacts (40 MHz HPU clock, 250 MHz Corundum domain, module
latencies) are not portable to this substrate, so the *paper-faithful*
latency numbers are reproduced through a structural analytic model built
from the published constants.  Magnitude parameters are calibrated once
against Fig 7 (documented below); all *shapes* — the linear ICMP slope,
the flat UDP curves, the Host/FPsPIN orderings, the ingress-DMA range of
Table II — emerge from the model structure, not from fitting curves.

Units: nanoseconds unless suffixed otherwise.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------- constants
FPSPIN_CLK_HZ = 40e6          # application block clock (paper §IV-A)
CORUNDUM_CLK_HZ = 250e6       # Corundum native clock
WIRE_GBPS = 100.0             # QSFP 100G loopback

CYC = 1e9 / FPSPIN_CLK_HZ     # 25 ns per FPsPIN cycle
CCYC = 1e9 / CORUNDUM_CLK_HZ  # 4 ns per Corundum cycle

# Table II (measured from RTL state machines)
MATCH_CYCLES = 4              # -> 100 ns
ALLOC_CYCLES = 0
HER_CYCLES = 0
INGRESS_DMA_CYCLES_MIN = 8    # 64 B packet  -> 200 ns
INGRESS_DMA_CYCLES_MAX = 70   # 1536 B packet -> 1750 ns
HOST_DMA_NS = 450             # PCIe path, 250 MHz domain

# Calibration (Fig 7 magnitudes; see docstring):
CORUNDUM_PIPELINE_NS = 2_000        # MAC+PHY+ingress pipeline, per direction
HANDLER_BASE_CYCLES = 600           # handler dispatch + header rewrite
FPSPIN_CHECKSUM_CYC_PER_BYTE = 2.0  # portable-C csum on a 40 MHz HPU
HOST_CHECKSUM_SPEEDUP = 2.0         # paper: FPsPIN core only 2x slower
HOST_KERNEL_STACK_NS = 25_000       # interrupt + kernel ICMP responder
HOST_UDP_EXTRA_NS = 40_000          # paper: UDP stack + user-space ~40 us
HOST_NIC_IRQ_NS = 10_000            # NIC->host wakeup


def wire_ns(nbytes: int) -> float:
    return nbytes * 8 / WIRE_GBPS


def ingress_dma_ns(nbytes: int) -> float:
    """Linear in packet size between the Table II endpoints."""
    frac = min(max((nbytes - 64) / (1536 - 64), 0.0), 1.0)
    cyc = INGRESS_DMA_CYCLES_MIN + frac * (
        INGRESS_DMA_CYCLES_MAX - INGRESS_DMA_CYCLES_MIN)
    return cyc * CYC


def match_ns() -> float:
    return MATCH_CYCLES * CYC


def handler_ns(payload: int, checksum: bool) -> float:
    c = HANDLER_BASE_CYCLES
    if checksum:
        c += FPSPIN_CHECKSUM_CYC_PER_BYTE * payload
    return c * CYC


def host_checksum_ns(payload: int) -> float:
    return FPSPIN_CHECKSUM_CYC_PER_BYTE * payload * CYC / \
        HOST_CHECKSUM_SPEEDUP


@dataclasses.dataclass
class RTTBreakdown:
    total_ns: float
    parts: dict


def pingpong_rtt_ns(mode: str, proto: str, payload: int) -> RTTBreakdown:
    """Median RTT model for Fig 7.

    mode  : 'host' | 'fpspin' | 'host+fpspin'
    proto : 'icmp' | 'udp'
    """
    frame = 42 + payload if proto == "icmp" else 42 + payload
    parts = {"wire": 2 * wire_ns(frame),
             "corundum": 2 * CORUNDUM_PIPELINE_NS}
    if mode == "host":
        parts["nic_to_host"] = HOST_DMA_NS + HOST_NIC_IRQ_NS
        parts["host_stack"] = HOST_KERNEL_STACK_NS
        if proto == "udp":
            # responder in user space: stack traversal + context switch
            parts["udp_stack"] = HOST_UDP_EXTRA_NS
        # kernel checksum is vectorized — negligible slope
        parts["host_to_nic"] = HOST_DMA_NS
    elif mode == "fpspin":
        parts["match"] = match_ns()
        parts["ingress_dma"] = ingress_dma_ns(frame)
        parts["handler"] = handler_ns(frame - 34, checksum=proto == "icmp")
        parts["egress_dma"] = ingress_dma_ns(frame)
    elif mode == "host+fpspin":
        parts["match"] = match_ns()
        parts["ingress_dma"] = ingress_dma_ns(frame)
        parts["handler"] = handler_ns(0, checksum=False)
        parts["host_dma"] = 2 * HOST_DMA_NS           # to host and back
        if proto == "icmp":
            parts["host_csum"] = host_checksum_ns(frame - 34)
        parts["egress_dma"] = ingress_dma_ns(frame)
    else:
        raise ValueError(mode)
    return RTTBreakdown(total_ns=sum(parts.values()), parts=parts)


def table2() -> dict:
    """Reproduce paper Table II verbatim from the model constants."""
    return {
        "matching_engine": {"cycles": MATCH_CYCLES, "mhz": 40,
                            "ns": MATCH_CYCLES * CYC},
        "allocator": {"cycles": ALLOC_CYCLES, "mhz": 40, "ns": 0.0},
        "ingress_dma": {"cycles": (INGRESS_DMA_CYCLES_MIN,
                                   INGRESS_DMA_CYCLES_MAX), "mhz": 40,
                        "ns": (ingress_dma_ns(64), ingress_dma_ns(1536))},
        "her_generator": {"cycles": HER_CYCLES, "mhz": 40, "ns": 0.0},
        "host_dma": {"cycles": None, "mhz": 250, "ns": HOST_DMA_NS},
    }


def slmp_goodput_gbps(window: int, mtu_payload: int = 1484,
                      rtt_ns: float = 30_000,
                      recv_pkt_ns: float = 2_600,
                      recv_buf_pkts: int = 170) -> tuple:
    """Fig 8 model: windowed sender over a 100G loop.

    Sender pushes `window` segments then waits for the window's ACKs.
    Receiver drains one segment per `recv_pkt_ns` (ingress DMA + handler +
    host DMA, ~2.6 us for MTU frames).  Goodput saturates at the receiver
    rate; when the in-flight window exceeds the large-slot FIFO depth
    (170 slots, Table I-derived), allocation fails and transfers start
    failing — returns (gbps, fail_probability).
    """
    seg_wire = wire_ns(mtu_payload + 52)
    window_time = max(window * seg_wire, window * recv_pkt_ns) + rtt_ns
    gbps = window * mtu_payload * 8 / window_time
    overflow = max(0.0, (window - recv_buf_pkts) / max(window, 1))
    fail_p = min(1.0, 3.0 * overflow)
    return gbps, fail_p
