"""SLMP — the Simple Lossy Message Protocol of paper §V-B.

10-byte header inside the UDP payload: FLAGS u16 {SYN, ACK, EOM},
MSG_ID u32, OFFSET u32.  The receiver side is implemented *entirely in
sPIN handlers* (as in the paper):

  header handler : sets up the message context (marks active, zeroes the
                   received-byte count in per-message state);
  packet handler : DMAs the payload to host memory at ``OFFSET`` (the
                   byte-granular, unaligned-capable hostmem path), counts
                   received bytes, and answers SYN segments with an ACK;
  tail handler   : pushes ``msg_id`` into counter queue 0 — the host
                   completion notification.

Sender-side segmentation and the window/flow-control policies (per-packet
ACK with window=1 → in-order processing; windowed SYN on first/last for
message-level reliability) are host-side utilities used by the file
transfer example, the DDT pipeline and the Fig-8 benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import handlers as H
from repro.core import matching
from repro.core import packet as pkt

COMPLETION_QUEUE = 0
ACK_QUEUE = 1


# ------------------------------------------------------------ receiver side
def _mk_ack(data, length):
    """Build an ACK from a received segment: swap L2/L3/L4 endpoints, set
    ACK flag, drop the payload (header-only segment)."""
    d = pkt.swap_bytes(data, pkt.ETH_DST, pkt.ETH_SRC, 6)
    d = pkt.swap_bytes(d, pkt.IP_SRC, pkt.IP_DST, 4)
    d = pkt.swap_bytes(d, pkt.UDP_SPORT, pkt.UDP_DPORT, 2)
    flags = pkt.read_u16(d, pkt.SLMP_FLAGS)
    d = pkt.write_u16(d, pkt.SLMP_FLAGS, flags | pkt.SLMP_FLAG_ACK)
    d = pkt.write_u16(d, pkt.UDP_LEN, 8 + pkt.SLMP_HDR_BYTES)
    d = pkt.write_u16(d, pkt.IP_TOTLEN, 20 + 8 + pkt.SLMP_HDR_BYTES)
    # zero stale payload bytes beyond the new length
    lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
    d = jnp.where(lane < pkt.SLMP_PAYLOAD, d, 0).astype(jnp.uint8)
    return d, jnp.asarray(pkt.SLMP_PAYLOAD, jnp.int32)


def slmp_header_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    # state[0] = active flag, state[1] = bytes received (assoc. counters)
    out = H.add_msg_state(out, 0, 1)
    return out


def slmp_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    offset = pkt.read_u32(args.pkt, pkt.SLMP_OFFSET).astype(jnp.int32)
    flags = pkt.read_u16(args.pkt, pkt.SLMP_FLAGS)
    plen = args.pkt_len - pkt.SLMP_PAYLOAD
    # payload -> host[offset : offset+plen]  (window=1 gives in-order)
    lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
    live = (lane >= pkt.SLMP_PAYLOAD) & (lane < args.pkt_len)
    dma_off = jnp.where(live, offset + (lane - pkt.SLMP_PAYLOAD), -1)
    out = H.spin_dma_scatter(out, dma_off, args.pkt)
    out = H.add_msg_state(out, 1, plen)
    # SYN -> echo an ACK segment
    ack_data, ack_len = _mk_ack(args.pkt, args.pkt_len)
    syn = (flags & pkt.SLMP_FLAG_SYN) != 0
    out = out._replace(
        egress_data=ack_data,
        egress_len=jnp.where(syn, ack_len, 0),
        egress_valid=syn.astype(bool))
    return out


def slmp_tail_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    # completion notification: msg_id to the host FIFO
    return H.push_counter(out, COMPLETION_QUEUE,
                          args.msg_id.astype(jnp.int32))


def make_slmp_context(port: int = 9330, host_base: int = 0,
                      host_size: int = 1 << 20, name: str = "slmp",
                      packet_handler=slmp_packet_handler,
                      user=None) -> H.ExecutionContext:
    return H.ExecutionContext(
        name=name, ruleset=matching.ruleset_slmp(port),
        header=slmp_header_handler, packet=packet_handler,
        tail=slmp_tail_handler, user=user,
        host_base=host_base, host_size=host_size, message_mode=True)


# ------------------------------------------------------------- sender side
@dataclasses.dataclass
class SlmpSenderConfig:
    window: int = 16            # segments in flight before waiting for ACKs
    mtu_payload: int = pkt.MAX_SLMP_PAYLOAD
    syn_every_packet: bool = True   # window-mode: every segment SYN+ACKed
    port: int = 9330


def segment_message(msg: np.ndarray, msg_id: int,
                    cfg: SlmpSenderConfig) -> List[np.ndarray]:
    """Split a message into SLMP segments (wire frames, numpy)."""
    frames = []
    n = len(msg)
    nseg = max(1, (n + cfg.mtu_payload - 1) // cfg.mtu_payload)
    for s in range(nseg):
        off = s * cfg.mtu_payload
        payload = msg[off:off + cfg.mtu_payload]
        flags = 0
        if cfg.syn_every_packet or s == 0 or s == nseg - 1:
            flags |= pkt.SLMP_FLAG_SYN
        if s == nseg - 1:
            flags |= pkt.SLMP_FLAG_EOM
        frames.append(pkt.make_slmp(msg_id, off, flags, payload,
                                    dport=cfg.port))
    return frames


def parse_acks(batch: pkt.PacketBatch) -> List[tuple]:
    """Host-side: extract (msg_id, offset) from ACK segments in a batch."""
    data = np.asarray(batch.data)
    valid = np.asarray(batch.valid)
    acks = []
    for i in range(len(valid)):
        if not valid[i]:
            continue
        flags = (int(data[i, pkt.SLMP_FLAGS]) << 8) | int(
            data[i, pkt.SLMP_FLAGS + 1])
        if flags & pkt.SLMP_FLAG_ACK:
            msg_id = int.from_bytes(bytes(data[i, pkt.SLMP_MSGID:
                                               pkt.SLMP_MSGID + 4]), "big")
            off = int.from_bytes(bytes(data[i, pkt.SLMP_OFFSET:
                                            pkt.SLMP_OFFSET + 4]), "big")
            acks.append((msg_id, off))
    return acks
