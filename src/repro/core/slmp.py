"""SLMP — the Simple Lossy Message Protocol of paper §V-B.

10-byte header inside the UDP payload: FLAGS u16 {SYN, ACK, EOM},
MSG_ID u32, OFFSET u32.  The receiver side is implemented *entirely in
sPIN handlers* (as in the paper):

  header handler : sets up the message context (marks active, zeroes the
                   received-byte count in per-message state);
  packet handler : DMAs the payload to host memory at ``OFFSET`` (the
                   byte-granular, unaligned-capable hostmem path), counts
                   received bytes, and answers SYN segments with an ACK;
  tail handler   : pushes ``msg_id`` into counter queue 0 — the host
                   completion notification.

Sender-side segmentation and the window/flow-control policies (per-packet
ACK with window=1 → in-order processing; windowed SYN on first/last for
message-level reliability) are host-side utilities used by the file
transfer example, the DDT pipeline and the Fig-8 benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import handlers as H
from repro.core import matching
from repro.core import packet as pkt

COMPLETION_QUEUE = 0
ACK_QUEUE = 1


# ------------------------------------------------------------ receiver side
def _mk_ack(data, length):
    """Build an ACK from a received segment: swap L2/L3/L4 endpoints, set
    ACK flag, drop the payload (header-only segment)."""
    d = pkt.swap_bytes(data, pkt.ETH_DST, pkt.ETH_SRC, 6)
    d = pkt.swap_bytes(d, pkt.IP_SRC, pkt.IP_DST, 4)
    d = pkt.swap_bytes(d, pkt.UDP_SPORT, pkt.UDP_DPORT, 2)
    flags = pkt.read_u16(d, pkt.SLMP_FLAGS)
    d = pkt.write_u16(d, pkt.SLMP_FLAGS, flags | pkt.SLMP_FLAG_ACK)
    d = pkt.write_u16(d, pkt.UDP_LEN, 8 + pkt.SLMP_HDR_BYTES)
    d = pkt.write_u16(d, pkt.IP_TOTLEN, 20 + 8 + pkt.SLMP_HDR_BYTES)
    # zero stale payload bytes beyond the new length
    lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
    d = jnp.where(lane < pkt.SLMP_PAYLOAD, d, 0).astype(jnp.uint8)
    return d, jnp.asarray(pkt.SLMP_PAYLOAD, jnp.int32)


def slmp_header_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    # state[0] = active flag, state[1] = bytes received (assoc. counters)
    out = H.add_msg_state(out, 0, 1)
    return out


def slmp_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    offset = pkt.read_u32(args.pkt, pkt.SLMP_OFFSET).astype(jnp.int32)
    flags = pkt.read_u16(args.pkt, pkt.SLMP_FLAGS)
    plen = args.pkt_len - pkt.SLMP_PAYLOAD
    # payload -> host[offset : offset+plen]  (window=1 gives in-order)
    lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
    live = (lane >= pkt.SLMP_PAYLOAD) & (lane < args.pkt_len)
    dma_off = jnp.where(live, offset + (lane - pkt.SLMP_PAYLOAD), -1)
    out = H.spin_dma_scatter(out, dma_off, args.pkt)
    out = H.add_msg_state(out, 1, plen)
    # SYN -> echo an ACK segment
    ack_data, ack_len = _mk_ack(args.pkt, args.pkt_len)
    syn = (flags & pkt.SLMP_FLAG_SYN) != 0
    out = out._replace(
        egress_data=ack_data,
        egress_len=jnp.where(syn, ack_len, 0),
        egress_valid=syn.astype(bool))
    return out


def slmp_tail_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    # Completion notification: msg_id to the host FIFO.  Semantics under a
    # lossy wire are *at-least-once, EOM-triggered*: the tail handler runs
    # on every arrival of an EOM segment (including retransmits whose ACK
    # was lost), and may precede hole-filling retransmissions of earlier
    # segments.  Byte-completeness is the sender's job — SLMP reliability
    # is ACK-driven (SlmpSender.done); receivers that need a "all bytes
    # landed" signal use that, as the examples/benchmarks do.  (An exact
    # in-handler completeness check would need per-segment receive state
    # that survives duplicate deliveries; the VM's associative-add message
    # state double-counts duplicates, so we keep the paper's EOM trigger.)
    return H.push_counter(out, COMPLETION_QUEUE,
                          args.msg_id.astype(jnp.int32))


def make_slmp_context(port: int = 9330, host_base: int = 0,
                      host_size: int = 1 << 20, name: str = "slmp",
                      packet_handler=slmp_packet_handler,
                      user=None) -> H.ExecutionContext:
    return H.ExecutionContext(
        name=name, ruleset=matching.ruleset_slmp(port),
        header=slmp_header_handler, packet=packet_handler,
        tail=slmp_tail_handler, user=user,
        host_base=host_base, host_size=host_size, message_mode=True)


# ------------------------------------------------------------- sender side
@dataclasses.dataclass
class SlmpSenderConfig:
    window: int = 16            # segments in flight before waiting for ACKs
    mtu_payload: int = pkt.MAX_SLMP_PAYLOAD
    syn_every_packet: bool = True   # window-mode: every segment SYN+ACKed
    port: int = 9330
    timeout: int = 8            # ticks before an unACKed segment retransmits
    max_retries: int = 32       # per-segment retransmit budget
    src_mac: Optional[bytes] = None
    dst_mac: Optional[bytes] = None


def segment_message(msg: np.ndarray, msg_id: int,
                    cfg: SlmpSenderConfig) -> List[np.ndarray]:
    """Split a message into SLMP segments (wire frames, numpy)."""
    frames = []
    n = len(msg)
    nseg = max(1, (n + cfg.mtu_payload - 1) // cfg.mtu_payload)
    for s in range(nseg):
        off = s * cfg.mtu_payload
        payload = msg[off:off + cfg.mtu_payload]
        flags = 0
        if cfg.syn_every_packet or s == 0 or s == nseg - 1:
            flags |= pkt.SLMP_FLAG_SYN
        if s == nseg - 1:
            flags |= pkt.SLMP_FLAG_EOM
        frames.append(pkt.make_slmp(msg_id, off, flags, payload,
                                    dport=cfg.port, src_mac=cfg.src_mac,
                                    dst_mac=cfg.dst_mac))
    return frames


class SlmpSender:
    """Windowed, reliable SLMP sender as a tick-steppable state machine.

    The paper's sender (§V-B) keeps up to ``window`` segments in flight;
    each SYN segment is ACKed by the sPIN packet handler on the receiver.
    A segment whose ACK has not arrived ``timeout`` ticks after its last
    transmission is retransmitted (up to ``max_retries`` times) — the
    retransmission path that makes SLMP survive a lossy link.

    Drive it with ``poll(now)`` (frames to put on the wire this tick) and
    ``on_ack(msg_id, offset)`` for every ACK observed.  Retransmission
    needs per-segment ACKs, so the state machine forces SYN on every
    segment (``syn_every_packet``).
    """

    def __init__(self, msg: np.ndarray, msg_id: int,
                 cfg: Optional[SlmpSenderConfig] = None):
        cfg = dataclasses.replace(cfg or SlmpSenderConfig(),
                                  syn_every_packet=True)
        self.cfg = cfg
        self.msg_id = msg_id
        self.nbytes = len(msg)
        self.frames = segment_message(msg, msg_id, cfg)
        self.nseg = len(self.frames)
        self.acked = np.zeros(self.nseg, bool)
        self.last_sent = np.full(self.nseg, -1, np.int64)
        self.retries = np.zeros(self.nseg, np.int32)
        self.sent_frames = 0
        self.retransmits = 0

    @property
    def done(self) -> bool:
        return bool(self.acked.all())

    @property
    def failed(self) -> bool:
        return bool((self.retries > self.cfg.max_retries).any())

    def on_ack(self, msg_id: int, offset: int) -> None:
        if msg_id != self.msg_id:
            return
        seg = offset // self.cfg.mtu_payload
        if 0 <= seg < self.nseg:
            self.acked[seg] = True

    def poll(self, now: int) -> List[np.ndarray]:
        """Frames to transmit at tick ``now`` (new segments fill the window,
        timed-out segments retransmit)."""
        if self.done or self.failed:
            return []
        sent = self.last_sent >= 0
        timed_out = sent & ~self.acked & (
            now - self.last_sent >= self.cfg.timeout)
        inflight = int((sent & ~self.acked & ~timed_out).sum())
        budget = max(0, self.cfg.window - inflight)
        # retransmissions first (oldest data unblocks the receiver), then
        # new segments in offset order
        segs = (np.flatnonzero(timed_out).tolist()
                + np.flatnonzero(~sent).tolist())[:budget]
        out = []
        for s in segs:
            if self.last_sent[s] >= 0:
                self.retries[s] += 1
                if self.retries[s] > self.cfg.max_retries:
                    continue               # budget exhausted: nothing sent
                self.retransmits += 1
            self.last_sent[s] = now
            self.sent_frames += 1
            out.append(self.frames[s])
        return out

    # -- checkpoint support (net fabric snapshots) ------------------------
    def snapshot(self) -> dict:
        return dict(acked=self.acked.copy(), last_sent=self.last_sent.copy(),
                    retries=self.retries.copy(),
                    sent_frames=self.sent_frames,
                    retransmits=self.retransmits)

    def restore(self, snap: dict) -> None:
        self.acked = snap["acked"].copy()
        self.last_sent = snap["last_sent"].copy()
        self.retries = snap["retries"].copy()
        self.sent_frames = snap["sent_frames"]
        self.retransmits = snap["retransmits"]


def parse_acks(batch: pkt.PacketBatch) -> List[tuple]:
    """Host-side: extract (msg_id, offset) from ACK segments in a batch."""
    data = np.asarray(batch.data)
    valid = np.asarray(batch.valid)
    acks = []
    for i in range(len(valid)):
        if not valid[i]:
            continue
        flags = (int(data[i, pkt.SLMP_FLAGS]) << 8) | int(
            data[i, pkt.SLMP_FLAGS + 1])
        if flags & pkt.SLMP_FLAG_ACK:
            msg_id = int.from_bytes(bytes(data[i, pkt.SLMP_MSGID:
                                               pkt.SLMP_MSGID + 4]), "big")
            off = int.from_bytes(bytes(data[i, pkt.SLMP_OFFSET:
                                            pkt.SLMP_OFFSET + 4]), "big")
            acks.append((msg_id, off))
    return acks
