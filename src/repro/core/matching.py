"""Execution contexts and the FPsPIN matching engine (paper §IV, block 1).

A *rule* is ``(idx, mask, start, end)``: it matches a packet iff the 32-bit
big-endian word at byte index ``4*idx .. 4*idx+3``, AND-ed with ``mask``,
lies in ``[start, end]``.  Three rules are combined with AND or OR to decide
whether a packet belongs to an execution context; a fourth rule (same
format) marks the packet as end-of-message (EOM).  This is exactly the
iptables-U32 style engine of the paper, including the predefined rules
``FPSPIN_RULE_IP``, ``FPSPIN_RULE_IP_PROTO(n)``, ``FPSPIN_RULE_FALSE`` and
the ICMP-echo example from Listing 2 / Fig 6.

Vectorized execution lives in :mod:`repro.kernels.matcher` (Pallas kernel +
jnp reference); this module owns the data model and the host API
(``fpspin_ruleset_t`` equivalents).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet as pkt
from repro.kernels.matcher import ops as matcher_ops

MODE_AND = 0
MODE_OR = 1
RULES_PER_SET = 4            # 3 match rules + 1 EOM rule (paper §IV-C)
RULE_FIELDS = 4              # idx, mask, start, end


@dataclasses.dataclass(frozen=True)
class Rule:
    idx: int                 # 32-bit word index (byte offset / 4)
    mask: int
    start: int
    end: int

    def as_row(self) -> np.ndarray:
        return np.array([self.idx, self.mask, self.start, self.end],
                        np.uint32)


# Predefined rules, mirroring fpspin.h ------------------------------------
def RULE_FALSE() -> Rule:
    # never matches: empty range on a masked-out word
    return Rule(idx=0, mask=0, start=1, end=0)


def RULE_TRUE() -> Rule:
    return Rule(idx=0, mask=0, start=0, end=0)


def RULE_IP() -> Rule:
    # ethertype == 0x0800: bytes 12:14 live in word 3 (bytes 12..15), top half
    return Rule(idx=3, mask=0xFFFF0000, start=0x08000000, end=0x08000000)


def RULE_IP_PROTO(proto: int) -> Rule:
    # IP proto is byte 23 -> word 5 (bytes 20..23), lowest byte
    return Rule(idx=5, mask=0x000000FF, start=proto, end=proto)


def RULE_ICMP_ECHO_REQ() -> Rule:
    # Listing 2: byte 34 == 8 -> word 8 (bytes 32..35), mask 0xff00 on the
    # upper half-word... byte 34 is the third byte of word 8 -> bits 15:8.
    return Rule(idx=8, mask=0x0000FF00, start=0x0800, end=0x0800)


def RULE_UDP_DPORT(port: int) -> Rule:
    # UDP dst port bytes 36:38 -> word 9 (bytes 36..39), top half
    return Rule(idx=9, mask=0xFFFF0000, start=port << 16, end=port << 16)


def RULE_SLMP_EOM() -> Rule:
    # SLMP flags u16 at bytes 42:44 -> word 10 holds bytes 40..43; flags'
    # first byte (42) sits at bits 15:8, second (43) at bits 7:0.  EOM bit
    # (0x0004) is in the low byte => match (word & 0x4) == 0x4.
    return Rule(idx=10, mask=pkt.SLMP_FLAG_EOM, start=pkt.SLMP_FLAG_EOM,
                end=pkt.SLMP_FLAG_EOM)


@dataclasses.dataclass(frozen=True)
class Ruleset:
    """``fpspin_ruleset_t``: mode + 3 match rules + 1 EOM rule."""
    mode: int
    rules: Sequence[Rule]            # exactly 3
    eom: Rule

    def __post_init__(self):
        assert len(self.rules) == RULES_PER_SET - 1, "need exactly 3 rules"

    def as_array(self) -> np.ndarray:
        rows = [r.as_row() for r in self.rules] + [self.eom.as_row()]
        return np.stack(rows).astype(np.uint32)


def ruleset_none() -> Ruleset:
    """Matches nothing: every frame forwards to the Corundum/host datapath.
    Used by fabric nodes whose traffic is all host-side (e.g. senders)."""
    return Ruleset(mode=MODE_AND,
                   rules=[RULE_FALSE(), RULE_FALSE(), RULE_FALSE()],
                   eom=RULE_FALSE())


def ruleset_icmp_echo() -> Ruleset:
    """The paper's Listing-2 example: match ICMP Echo-Requests, no EOM."""
    return Ruleset(mode=MODE_AND,
                   rules=[RULE_IP(), RULE_IP_PROTO(pkt.IPPROTO_ICMP),
                          RULE_ICMP_ECHO_REQ()],
                   eom=RULE_FALSE())


def ruleset_udp_pingpong(port: int = 9999) -> Ruleset:
    return Ruleset(mode=MODE_AND,
                   rules=[RULE_IP(), RULE_IP_PROTO(pkt.IPPROTO_UDP),
                          RULE_UDP_DPORT(port)],
                   eom=RULE_FALSE())


def ruleset_slmp(port: int = 9330) -> Ruleset:
    """Match SLMP segments; EOM taken from the SLMP flags EOM bit."""
    return Ruleset(mode=MODE_AND,
                   rules=[RULE_IP(), RULE_IP_PROTO(pkt.IPPROTO_UDP),
                          RULE_UDP_DPORT(port)],
                   eom=RULE_SLMP_EOM())


@dataclasses.dataclass
class MatchTables:
    """Device-side form of all installed execution contexts' rulesets.

    rules: (C, 4, 4) uint32  (context, rule, [idx,mask,start,end])
    modes: (C,) int32
    """
    rules: jax.Array
    modes: jax.Array

    @staticmethod
    def build(rulesets: List[Ruleset]) -> "MatchTables":
        rules = np.stack([rs.as_array() for rs in rulesets])
        modes = np.array([rs.mode for rs in rulesets], np.int32)
        return MatchTables(jnp.asarray(rules), jnp.asarray(modes))

    @property
    def n_ctx(self) -> int:
        return self.rules.shape[0]


def match_batch(batch: pkt.PacketBatch, tables: MatchTables,
                use_kernel: bool = False):
    """Run the matching engine over a batch.

    Returns ``(ctx_id, eom)``: ctx_id (N,) int32, -1 when no context matches
    (packet is forwarded to the Corundum/host datapath); eom (N,) bool.
    Lowest-numbered matching context wins (priority order, as in hardware
    rule tables).
    """
    words = batch.words()                       # (N, W) uint32
    matched, eom = matcher_ops.match(words, tables.rules, tables.modes,
                                     use_kernel=use_kernel)   # (N, C) bool ×2
    matched = jnp.logical_and(matched, batch.valid[:, None])
    any_match = matched.any(axis=1)
    first = jnp.argmax(matched, axis=1).astype(jnp.int32)
    ctx_id = jnp.where(any_match, first, -1)
    eom_hit = jnp.take_along_axis(
        eom, jnp.maximum(first, 0)[:, None], axis=1)[:, 0]
    return ctx_id, jnp.logical_and(any_match, eom_hit)
