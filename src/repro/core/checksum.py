"""Internet checksum — handler-side (vmappable) and batched-kernel forms.

The paper's ICMP responder computes the RFC1071 checksum in portable C
inside the packet handler; Fig 7 shows this dominates the RTT slope.  We
provide:

* ``internet_checksum_1`` — single-packet jnp form, used *inside* handlers
  (vmapped by the VM, so it is effectively batched anyway);
* the Pallas kernel path (:mod:`repro.kernels.checksum`) — the TPU-native
  batched version used by benchmarks and the optimized responder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packet import MTU
from repro.kernels.checksum import ops as checksum_ops  # re-export


def internet_checksum_1(data: jax.Array, length: jax.Array, start: int
                        ) -> jax.Array:
    """Checksum of bytes [start, length) of one packet buffer (MTU,).

    Bytes beyond ``length`` must be zero (PacketBatch invariant)."""
    b = data.astype(jnp.uint32).reshape(MTU // 2, 2)
    words = (b[:, 0] << 8) | b[:, 1]
    w_iota = jnp.arange(MTU // 2, dtype=jnp.int32)
    live = (w_iota >= start // 2) & (w_iota < (length + 1) // 2)
    s = jnp.sum(jnp.where(live, words, 0))
    s = (s & 0xFFFF) + (s >> 16)
    s = (s & 0xFFFF) + (s >> 16)
    return ((~s) & 0xFFFF).astype(jnp.uint32)


def internet_checksum_batch(data, lengths, start: int, use_kernel=False):
    return checksum_ops.internet_checksum(data, lengths, start=start,
                                          use_kernel=use_kernel)
