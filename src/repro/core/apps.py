"""Built-in sPIN handler applications (paper Listings 1–2 and §V-C).

* ICMP echo responder — the Listing 1/2 example: full-payload RFC1071
  checksum inside the packet handler.
* UDP ping-pong responder — checksum-free (UDP checksum optional/omitted).
* MPI DDT receive context — SLMP transport + datatype scatter into host
  memory using the committed index map (dataloop engine offload).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import checksum as ck
from repro.core import ddt as ddtlib
from repro.core import handlers as H
from repro.core import matching
from repro.core import packet as pkt
from repro.core import slmp


# ---------------------------------------------------------- host-only node
def make_null_context() -> H.ExecutionContext:
    """Matches nothing — the whole ingress stream takes the host datapath.
    Installed on fabric nodes that only run host-side engines."""
    return H.ExecutionContext(name="null", ruleset=matching.ruleset_none())


# ------------------------------------------------------------- ICMP echo
def icmp_echo_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    """Listing 1: swap MAC/IP, type=EchoReply, recompute full checksum."""
    out = H.none_out()
    d = args.pkt
    d = pkt.swap_bytes(d, pkt.ETH_DST, pkt.ETH_SRC, 6)
    d = pkt.swap_bytes(d, pkt.IP_SRC, pkt.IP_DST, 4)
    d = d.at[pkt.ICMP_TYPE].set(pkt.ICMP_ECHO_REPLY)
    d = pkt.write_u16(d, pkt.ICMP_CSUM, 0)
    c = ck.internet_checksum_1(d, args.pkt_len, pkt.L4_BASE)
    d = pkt.write_u16(d, pkt.ICMP_CSUM, c)
    return H.spin_send_packet(out, d, args.pkt_len)


def make_icmp_context() -> H.ExecutionContext:
    return H.ExecutionContext(
        name="icmp_echo", ruleset=matching.ruleset_icmp_echo(),
        packet=icmp_echo_packet_handler)


# ---------------------------------------------------------- UDP ping-pong
def udp_pingpong_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    d = args.pkt
    d = pkt.swap_bytes(d, pkt.ETH_DST, pkt.ETH_SRC, 6)
    d = pkt.swap_bytes(d, pkt.IP_SRC, pkt.IP_DST, 4)
    d = pkt.swap_bytes(d, pkt.UDP_SPORT, pkt.UDP_DPORT, 2)
    return H.spin_send_packet(out, d, args.pkt_len)


def make_udp_pingpong_context(port: int = 9999) -> H.ExecutionContext:
    return H.ExecutionContext(
        name="udp_pingpong", ruleset=matching.ruleset_udp_pingpong(port),
        packet=udp_pingpong_packet_handler)


# -------------------------------------------------- Host+FPsPIN ping mode
def icmp_to_host_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    """Host+FPsPIN mode: DMA the frame to host memory and notify; the host
    computes the checksum and injects the reply (bench_pingpong drives the
    host half)."""
    out = H.none_out()
    lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
    off = jnp.where(lane < args.pkt_len, lane, -1)
    out = H.spin_dma_scatter(out, off, args.pkt)
    return H.push_counter(out, slmp.COMPLETION_QUEUE, args.pkt_len)


def make_icmp_host_context(host_base: int = 0) -> H.ExecutionContext:
    return H.ExecutionContext(
        name="icmp_hostpath", ruleset=matching.ruleset_icmp_echo(),
        packet=icmp_to_host_packet_handler, host_base=host_base)


# --------------------------------------------------------- shared helpers
def _slmp_payload_lanes(args: H.HandlerArgs):
    """Per-lane view of an SLMP segment's payload: ``(msg_pos, live)``
    where ``msg_pos[l]`` is the message byte position lane ``l`` carries
    and ``live`` masks the payload lanes of this packet.  Shared prologue
    of every SLMP-transported scatter handler."""
    offset = pkt.read_u32(args.pkt, pkt.SLMP_OFFSET).astype(jnp.int32)
    lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
    msg_pos = offset + (lane - pkt.SLMP_PAYLOAD)
    live = (lane >= pkt.SLMP_PAYLOAD) & (lane < args.pkt_len)
    return msg_pos, live


def _ack_if_syn(out: H.HandlerOut, args: H.HandlerArgs) -> H.HandlerOut:
    """Per-packet SLMP ACK when the SYN flag is set (window-mode reliability,
    paper §V-B) — shared by every SLMP-transported handler app."""
    flags = pkt.read_u16(args.pkt, pkt.SLMP_FLAGS)
    ack_data, ack_len = slmp._mk_ack(args.pkt, args.pkt_len)
    syn = (flags & pkt.SLMP_FLAG_SYN) != 0
    return out._replace(egress_data=ack_data,
                        egress_len=jnp.where(syn, ack_len, 0),
                        egress_valid=syn.astype(bool))


# ------------------------------------------------------ MPI DDT processing
def make_ddt_packet_handler(committed: ddtlib.CommittedDDT,
                            msgs_in_flight: int = 16):
    """Packet handler for DDT receive: scatter payload bytes through the
    committed datatype's msg→mem map.  Parallel messages are placed at
    ``msg_id * mem_bytes`` (disjoint regions, as the paper's 16 concurrent
    messages)."""
    msg_to_mem = jnp.asarray(committed.msg_to_mem)
    mem_bytes = committed.mem_bytes
    msg_len = committed.msg_bytes

    def ddt_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
        out = H.none_out()
        msg_pos, live = _slmp_payload_lanes(args)
        live = live & (msg_pos < msg_len)
        mem_off = jnp.take(msg_to_mem, jnp.clip(msg_pos, 0, msg_len - 1))
        region = (args.msg_id.astype(jnp.int32) % msgs_in_flight) * mem_bytes
        dma_off = jnp.where(live, region + mem_off, -1)
        out = H.spin_dma_scatter(out, dma_off, args.pkt)
        out = H.add_msg_state(out, 1, args.pkt_len - pkt.SLMP_PAYLOAD)
        # per-packet ACK when SYN set (window=1 mode in the paper's runs)
        return _ack_if_syn(out, args)

    return ddt_packet_handler


def make_ddt_context(committed: ddtlib.CommittedDDT, port: int = 9331,
                     msgs_in_flight: int = 16, host_base: int = 0
                     ) -> H.ExecutionContext:
    return slmp.make_slmp_context(
        port=port, host_base=host_base,
        host_size=committed.mem_bytes * msgs_in_flight,
        name="mpi_ddt",
        packet_handler=make_ddt_packet_handler(committed, msgs_in_flight))


# ----------------------------------------------- MPI messaging (repro.mpi)
# msg_id bit layout shared between the host MPI library (repro.mpi.wire)
# and the NIC handlers below.  The MPQ masks msg_id to 28 bits, so the
# whole encoding must stay below bit 28:
#
#     [25:24] kind (1 = eager, 2 = rendezvous)
#     [23:16] datatype id (rendezvous only)
#     [15:0]  staging / rendezvous slot on the receiver
MPI_KIND_EAGER = 1
MPI_KIND_RDV = 2
MPI_MSGID_KIND_SHIFT = 24
MPI_MSGID_DTYPE_SHIFT = 16
MPI_MSGID_DTYPE_MASK = 0xFF
MPI_MSGID_SLOT_MASK = 0xFFFF

# How many times each MPI NIC context (and its device tables) has been
# built this job.  A context build uploads the committed index maps to the
# device, so regression tests assert this stays flat when a second
# communicator reuses the same datatype tables (the repro.mpi NIC cache).
MPI_CONTEXT_BUILDS = dict(eager=0, ddt=0)


def make_mpi_eager_context(port: int, n_slots: int, slot_bytes: int,
                           host_base: int = 0) -> H.ExecutionContext:
    """Eager-protocol receive context: each message lands in a per-sender
    staging slot of the host window (slot index in the low msg_id bits);
    the host matches tags and copies out after the sender's FIN.  The NIC
    does reassembly + per-packet ACK; the host never touches a wire frame.
    """
    MPI_CONTEXT_BUILDS["eager"] += 1

    def eager_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
        out = H.none_out()
        msg_id = args.msg_id.astype(jnp.int32)
        slot = msg_id & MPI_MSGID_SLOT_MASK
        rel, live = _slmp_payload_lanes(args)
        live = live & (rel < slot_bytes) & (slot < n_slots)
        dma_off = jnp.where(live, slot * slot_bytes + rel, -1)
        out = H.spin_dma_scatter(out, dma_off, args.pkt)
        out = H.add_msg_state(out, 1, args.pkt_len - pkt.SLMP_PAYLOAD)
        return _ack_if_syn(out, args)

    return slmp.make_slmp_context(
        port=port, host_base=host_base, host_size=n_slots * slot_bytes,
        name="mpi_eager", packet_handler=eager_packet_handler)


def make_mpi_ddt_context(maps, msg_lens, region_bytes: int, n_slots: int,
                         port: int, host_base: int = 0
                         ) -> H.ExecutionContext:
    """Rendezvous receive context with *offloaded datatype processing*:
    payload bytes scatter through the committed msg→mem index map of the
    datatype named in the msg_id, straight into the posted receive region
    (``phys_slot * region_bytes``) of host memory — the dataloop-engine
    offload of paper §V-C, generalized to a table of committed datatypes.

    The msg_id's 16-bit slot field carries a *virtual* slot
    ``gen · n_slots + phys``: the host arms ``expect[phys]`` with the full
    msg_id before granting the CTS, and the handler drops any frame whose
    msg_id does not match — a stale retransmit of the region's previous
    occupant (still queued in a congested link) can never scribble a
    recycled slot, which is what lets the credit manager reuse slots the
    moment they FIN, with no quarantine delay.

    ``maps``: (D, Mmax) int32, msg→mem byte map per datatype, -1-padded;
    ``msg_lens``: (D,) int32 serialized size per datatype.
    """
    MPI_CONTEXT_BUILDS["ddt"] += 1
    maps = jnp.asarray(maps, jnp.int32)
    msg_lens = jnp.asarray(msg_lens, jnp.int32)
    n_types, max_msg = maps.shape
    assert n_types >= 1 and max_msg >= 1

    def mpi_ddt_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
        out = H.none_out()
        msg_id = args.msg_id.astype(jnp.int32)
        vslot = msg_id & MPI_MSGID_SLOT_MASK
        phys = vslot % n_slots
        dtype = (msg_id >> MPI_MSGID_DTYPE_SHIFT) & MPI_MSGID_DTYPE_MASK
        row = maps[jnp.clip(dtype, 0, n_types - 1)]
        msg_len = msg_lens[jnp.clip(dtype, 0, n_types - 1)]
        msg_pos, live = _slmp_payload_lanes(args)
        armed = jnp.take(args.expect, phys) == args.msg_id
        live = live & (msg_pos < msg_len) & (dtype < n_types) & armed
        mem_off = jnp.take(row, jnp.clip(msg_pos, 0, max_msg - 1))
        dma_off = jnp.where(live & (mem_off >= 0),
                            phys * region_bytes + mem_off, -1)
        out = H.spin_dma_scatter(out, dma_off, args.pkt)
        out = H.add_msg_state(out, 1, args.pkt_len - pkt.SLMP_PAYLOAD)
        return _ack_if_syn(out, args)

    ctx = slmp.make_slmp_context(
        port=port, host_base=host_base, host_size=n_slots * region_bytes,
        name="mpi_ddt_unpack", packet_handler=mpi_ddt_packet_handler)
    return dataclasses.replace(ctx, n_expect=n_slots)
