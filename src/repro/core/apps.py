"""Built-in sPIN handler applications (paper Listings 1–2 and §V-C).

* ICMP echo responder — the Listing 1/2 example: full-payload RFC1071
  checksum inside the packet handler.
* UDP ping-pong responder — checksum-free (UDP checksum optional/omitted).
* MPI DDT receive context — SLMP transport + datatype scatter into host
  memory using the committed index map (dataloop engine offload).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import checksum as ck
from repro.core import ddt as ddtlib
from repro.core import handlers as H
from repro.core import matching
from repro.core import packet as pkt
from repro.core import slmp


# ---------------------------------------------------------- host-only node
def make_null_context() -> H.ExecutionContext:
    """Matches nothing — the whole ingress stream takes the host datapath.
    Installed on fabric nodes that only run host-side engines."""
    return H.ExecutionContext(name="null", ruleset=matching.ruleset_none())


# ------------------------------------------------------------- ICMP echo
def icmp_echo_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    """Listing 1: swap MAC/IP, type=EchoReply, recompute full checksum."""
    out = H.none_out()
    d = args.pkt
    d = pkt.swap_bytes(d, pkt.ETH_DST, pkt.ETH_SRC, 6)
    d = pkt.swap_bytes(d, pkt.IP_SRC, pkt.IP_DST, 4)
    d = d.at[pkt.ICMP_TYPE].set(pkt.ICMP_ECHO_REPLY)
    d = pkt.write_u16(d, pkt.ICMP_CSUM, 0)
    c = ck.internet_checksum_1(d, args.pkt_len, pkt.L4_BASE)
    d = pkt.write_u16(d, pkt.ICMP_CSUM, c)
    return H.spin_send_packet(out, d, args.pkt_len)


def make_icmp_context() -> H.ExecutionContext:
    return H.ExecutionContext(
        name="icmp_echo", ruleset=matching.ruleset_icmp_echo(),
        packet=icmp_echo_packet_handler)


# ---------------------------------------------------------- UDP ping-pong
def udp_pingpong_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    out = H.none_out()
    d = args.pkt
    d = pkt.swap_bytes(d, pkt.ETH_DST, pkt.ETH_SRC, 6)
    d = pkt.swap_bytes(d, pkt.IP_SRC, pkt.IP_DST, 4)
    d = pkt.swap_bytes(d, pkt.UDP_SPORT, pkt.UDP_DPORT, 2)
    return H.spin_send_packet(out, d, args.pkt_len)


def make_udp_pingpong_context(port: int = 9999) -> H.ExecutionContext:
    return H.ExecutionContext(
        name="udp_pingpong", ruleset=matching.ruleset_udp_pingpong(port),
        packet=udp_pingpong_packet_handler)


# -------------------------------------------------- Host+FPsPIN ping mode
def icmp_to_host_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
    """Host+FPsPIN mode: DMA the frame to host memory and notify; the host
    computes the checksum and injects the reply (bench_pingpong drives the
    host half)."""
    out = H.none_out()
    lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
    off = jnp.where(lane < args.pkt_len, lane, -1)
    out = H.spin_dma_scatter(out, off, args.pkt)
    return H.push_counter(out, slmp.COMPLETION_QUEUE, args.pkt_len)


def make_icmp_host_context(host_base: int = 0) -> H.ExecutionContext:
    return H.ExecutionContext(
        name="icmp_hostpath", ruleset=matching.ruleset_icmp_echo(),
        packet=icmp_to_host_packet_handler, host_base=host_base)


# ------------------------------------------------------ MPI DDT processing
def make_ddt_packet_handler(committed: ddtlib.CommittedDDT,
                            msgs_in_flight: int = 16):
    """Packet handler for DDT receive: scatter payload bytes through the
    committed datatype's msg→mem map.  Parallel messages are placed at
    ``msg_id * mem_bytes`` (disjoint regions, as the paper's 16 concurrent
    messages)."""
    msg_to_mem = jnp.asarray(committed.msg_to_mem)
    mem_bytes = committed.mem_bytes
    msg_len = committed.msg_bytes

    def ddt_packet_handler(args: H.HandlerArgs, user) -> H.HandlerOut:
        out = H.none_out()
        offset = pkt.read_u32(args.pkt, pkt.SLMP_OFFSET).astype(jnp.int32)
        lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
        msg_pos = offset + (lane - pkt.SLMP_PAYLOAD)
        live = (lane >= pkt.SLMP_PAYLOAD) & (lane < args.pkt_len) \
            & (msg_pos < msg_len)
        mem_off = jnp.take(msg_to_mem, jnp.clip(msg_pos, 0, msg_len - 1))
        region = (args.msg_id.astype(jnp.int32) % msgs_in_flight) * mem_bytes
        dma_off = jnp.where(live, region + mem_off, -1)
        out = H.spin_dma_scatter(out, dma_off, args.pkt)
        out = H.add_msg_state(out, 1, args.pkt_len - pkt.SLMP_PAYLOAD)
        # per-packet ACK when SYN set (window=1 mode in the paper's runs)
        flags = pkt.read_u16(args.pkt, pkt.SLMP_FLAGS)
        ack_data, ack_len = slmp._mk_ack(args.pkt, args.pkt_len)
        syn = (flags & pkt.SLMP_FLAG_SYN) != 0
        return out._replace(egress_data=ack_data,
                            egress_len=jnp.where(syn, ack_len, 0),
                            egress_valid=syn.astype(bool))

    return ddt_packet_handler


def make_ddt_context(committed: ddtlib.CommittedDDT, port: int = 9331,
                     msgs_in_flight: int = 16, host_base: int = 0
                     ) -> H.ExecutionContext:
    return slmp.make_slmp_context(
        port=port, host_base=host_base,
        host_size=committed.mem_bytes * msgs_in_flight,
        name="mpi_ddt",
        packet_handler=make_ddt_packet_handler(committed, msgs_in_flight))
