"""Packet representation and protocol header layouts.

FPsPIN processes raw Ethernet frames.  We represent a batch of packets as a
``PacketBatch``: a ``(N, MTU) uint8`` array plus a length vector and a
validity mask.  All header fields live at the fixed byte offsets of
paper Fig. 6:

    Ethernet   bytes  0..13   (dst MAC 0:6, src MAC 6:12, ethertype 12:14)
    IPv4       bytes 14..33   (proto @23, src @26:30, dst @30:34, csum @24:26)
    ICMP       bytes 34..     (type @34, code @35, csum @36:38)
    UDP        bytes 34..41   (sport @34:36, dport @36:38, len @38:40,
                               csum @40:42)
    SLMP       bytes 42..51   (flags u16 @42, msg_id u32 @44, offset u32 @48)
    SLMP data  bytes 52..

Multi-byte fields are big-endian (network byte order), matching the
paper's matcher example (mask ``0xff00`` on word index 8 selects byte 34).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Constants (paper §IV: bimodal slot sizes; Ethernet MTU-sized frames).
MTU = 1536                      # large-slot size == max frame we carry
SMALL_SLOT = 128                # small-slot size
WORDS = MTU // 4                # 32-bit words per packet, for the matcher

# Header offsets (bytes).
ETH_DST, ETH_SRC, ETH_TYPE = 0, 6, 12
IP_BASE = 14
IP_VER_IHL = 14
IP_TOTLEN = 16
IP_ID = 18
IP_TTL = 22
IP_PROTO = 23
IP_CSUM = 24
IP_SRC = 26
IP_DST = 30
L4_BASE = 34
ICMP_TYPE = 34
ICMP_CODE = 35
ICMP_CSUM = 36
UDP_SPORT = 34
UDP_DPORT = 36
UDP_LEN = 38
UDP_CSUM = 40
SLMP_BASE = 42
SLMP_FLAGS = 42
SLMP_MSGID = 44
SLMP_OFFSET = 48
SLMP_PAYLOAD = 52
SLMP_HDR_BYTES = 10

ETH_P_IP = 0x0800
IPPROTO_ICMP = 1
IPPROTO_UDP = 17
ICMP_ECHO_REQUEST = 8
ICMP_ECHO_REPLY = 0

# SLMP flag bits (paper §V-B).
SLMP_FLAG_SYN = 1 << 0
SLMP_FLAG_ACK = 1 << 1
SLMP_FLAG_EOM = 1 << 2

MAX_SLMP_PAYLOAD = MTU - SLMP_PAYLOAD


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PacketBatch:
    """A batch of raw frames. ``data[i, :length[i]]`` are the live bytes."""

    data: jax.Array      # (N, MTU) uint8
    length: jax.Array    # (N,) int32
    valid: jax.Array     # (N,) bool

    def tree_flatten(self):
        return (self.data, self.length, self.valid), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.data.shape[0]

    def words(self) -> jax.Array:
        """(N, WORDS) uint32 big-endian word view, for the matching engine."""
        return bytes_to_u32be(self.data)

    @staticmethod
    def empty(n: int) -> "PacketBatch":
        return PacketBatch(
            data=jnp.zeros((n, MTU), jnp.uint8),
            length=jnp.zeros((n,), jnp.int32),
            valid=jnp.zeros((n,), bool),
        )


# ---------------------------------------------------------------------------
# Endian helpers (all pure jnp; operate on uint8 byte arrays).

def bytes_to_u32be(data: jax.Array) -> jax.Array:
    """uint8 (..., 4k) -> uint32 (..., k) big-endian."""
    b = data.astype(jnp.uint32).reshape(*data.shape[:-1], -1, 4)
    return (b[..., 0] << 24) | (b[..., 1] << 16) | (b[..., 2] << 8) | b[..., 3]


def bytes_to_u16be(data: jax.Array) -> jax.Array:
    b = data.astype(jnp.uint32).reshape(*data.shape[:-1], -1, 2)
    return ((b[..., 0] << 8) | b[..., 1]).astype(jnp.uint32)


def read_u16(data: jax.Array, off: int) -> jax.Array:
    """Big-endian u16 at static byte offset.  data: (..., bytes)."""
    return (data[..., off].astype(jnp.uint32) << 8) | data[..., off + 1]


def read_u32(data: jax.Array, off: int) -> jax.Array:
    return (
        (data[..., off].astype(jnp.uint32) << 24)
        | (data[..., off + 1].astype(jnp.uint32) << 16)
        | (data[..., off + 2].astype(jnp.uint32) << 8)
        | data[..., off + 3].astype(jnp.uint32)
    )


def write_u16(data: jax.Array, off: int, val) -> jax.Array:
    val = jnp.asarray(val, jnp.uint32)
    data = data.at[..., off].set((val >> 8).astype(jnp.uint8))
    return data.at[..., off + 1].set((val & 0xFF).astype(jnp.uint8))


def write_u32(data: jax.Array, off: int, val) -> jax.Array:
    val = jnp.asarray(val, jnp.uint32)
    for i in range(4):
        data = data.at[..., off + i].set(
            ((val >> (24 - 8 * i)) & 0xFF).astype(jnp.uint8))
    return data


def swap_bytes(data: jax.Array, a: int, b: int, n: int) -> jax.Array:
    """Swap byte ranges [a, a+n) and [b, b+n) (used to swap MAC/IP/ports)."""
    va = data[..., a:a + n]
    vb = data[..., b:b + n]
    data = jax.lax.dynamic_update_slice_in_dim(data, vb, a, axis=-1)
    return jax.lax.dynamic_update_slice_in_dim(data, va, b, axis=-1)


# ---------------------------------------------------------------------------
# Frame builders (host-side, numpy) — used by tests, benchmarks, examples
# and the packetized data pipeline.  These produce wire-correct frames so
# the matcher rules from the paper apply verbatim.

def _np_u16(buf: np.ndarray, off: int, val: int) -> None:
    buf[off] = (val >> 8) & 0xFF
    buf[off + 1] = val & 0xFF


def _np_u32(buf: np.ndarray, off: int, val: int) -> None:
    for i in range(4):
        buf[off + i] = (val >> (24 - 8 * i)) & 0xFF


def internet_checksum_np(data: np.ndarray) -> int:
    """RFC1071 ones-complement checksum of a byte array (numpy oracle)."""
    if len(data) % 2:
        data = np.concatenate([data, np.zeros(1, np.uint8)])
    words = (data[0::2].astype(np.uint32) << 8) | data[1::2]
    s = int(words.sum())
    while s >> 16:
        s = (s & 0xFFFF) + (s >> 16)
    return (~s) & 0xFFFF


def node_mac(node_id: int) -> bytes:
    """Locally-administered MAC for simulated node ``node_id`` (net fabric)."""
    return bytes([0x02, 0, 0, 0, (node_id >> 8) & 0xFF, node_id & 0xFF])


def build_eth_ip(buf: np.ndarray, proto: int, payload_len: int,
                 src_ip: int = 0x0A000001, dst_ip: int = 0x0A000002,
                 src_mac: Optional[bytes] = None,
                 dst_mac: Optional[bytes] = None) -> None:
    buf[ETH_DST:ETH_DST + 6] = np.frombuffer(
        dst_mac, np.uint8) if dst_mac is not None else \
        np.arange(6, dtype=np.uint8) + 0x10
    buf[ETH_SRC:ETH_SRC + 6] = np.frombuffer(
        src_mac, np.uint8) if src_mac is not None else \
        np.arange(6, dtype=np.uint8) + 0x20
    _np_u16(buf, ETH_TYPE, ETH_P_IP)
    buf[IP_VER_IHL] = 0x45
    _np_u16(buf, IP_TOTLEN, 20 + payload_len)
    _np_u16(buf, IP_ID, 1)
    buf[IP_TTL] = 64
    buf[IP_PROTO] = proto
    _np_u32(buf, IP_SRC, src_ip)
    _np_u32(buf, IP_DST, dst_ip)
    _np_u16(buf, IP_CSUM, 0)
    _np_u16(buf, IP_CSUM, internet_checksum_np(buf[IP_BASE:IP_BASE + 20]))


def make_icmp_echo(payload: np.ndarray, seq: int = 0,
                   src_mac: Optional[bytes] = None,
                   dst_mac: Optional[bytes] = None) -> np.ndarray:
    """Wire-correct ICMP Echo-Request frame (numpy uint8, len 42+payload)."""
    n = ICMP_CSUM + 6 + len(payload)
    buf = np.zeros(n, np.uint8)
    build_eth_ip(buf, IPPROTO_ICMP, 8 + len(payload),
                 src_mac=src_mac, dst_mac=dst_mac)
    buf[ICMP_TYPE] = ICMP_ECHO_REQUEST
    _np_u16(buf, ICMP_CSUM + 2, 0x1234)      # identifier
    _np_u16(buf, ICMP_CSUM + 4, seq)
    buf[L4_BASE + 8:] = payload
    _np_u16(buf, ICMP_CSUM, 0)
    _np_u16(buf, ICMP_CSUM, internet_checksum_np(buf[L4_BASE:]))
    return buf


def make_udp(payload: np.ndarray, sport: int = 9999, dport: int = 9999,
             src_mac: Optional[bytes] = None,
             dst_mac: Optional[bytes] = None) -> np.ndarray:
    n = SLMP_BASE + len(payload)
    buf = np.zeros(n, np.uint8)
    build_eth_ip(buf, IPPROTO_UDP, 8 + len(payload),
                 src_mac=src_mac, dst_mac=dst_mac)
    _np_u16(buf, UDP_SPORT, sport)
    _np_u16(buf, UDP_DPORT, dport)
    _np_u16(buf, UDP_LEN, 8 + len(payload))
    _np_u16(buf, UDP_CSUM, 0)                # paper: UDP csum omitted
    buf[SLMP_BASE:] = payload
    return buf


def make_slmp(msg_id: int, offset: int, flags: int, payload: np.ndarray,
              dport: int = 9330,
              src_mac: Optional[bytes] = None,
              dst_mac: Optional[bytes] = None) -> np.ndarray:
    """SLMP segment: 10-byte header inside the UDP payload (paper §V-B)."""
    body = np.zeros(SLMP_HDR_BYTES + len(payload), np.uint8)
    _np_u16(body, 0, flags)
    _np_u32(body, 2, msg_id)
    _np_u32(body, 6, offset)
    body[SLMP_HDR_BYTES:] = payload
    return make_udp(body, dport=dport, src_mac=src_mac, dst_mac=dst_mac)


def stack_frames(frames: list, n: Optional[int] = None) -> PacketBatch:
    """Pad a list of numpy frames into a PacketBatch (host-side)."""
    n = n if n is not None else len(frames)
    data = np.zeros((n, MTU), np.uint8)
    length = np.zeros((n,), np.int32)
    valid = np.zeros((n,), bool)
    for i, f in enumerate(frames):
        data[i, :len(f)] = f
        length[i] = len(f)
        valid[i] = True
    return PacketBatch(jnp.asarray(data), jnp.asarray(length),
                       jnp.asarray(valid))
