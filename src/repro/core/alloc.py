"""Bimodal fixed-slot packet-buffer allocator (paper §IV, block 2).

PsPIN's verilator testbench used a software ring buffer with out-of-order
frees — "difficult to implement in hardware".  FPsPIN instead partitions
the L2 packet buffer into two halves: fixed 128-byte slots and fixed
1536-byte slots, with free slots held in two FIFOs; allocation pops,
free pushes.  (Motivated by the bimodal Internet/datacenter packet-size
distribution: ~40 % <= 64 B, ~40 % ~1500 B.)

This is an exact functional reproduction: the FIFOs are circular buffers
in a pure-JAX ``AllocState``; a whole batch of requests is served in one
vectorized step (per-class ranks via cumsum — pops stay FIFO-ordered, and
once a class is exhausted every later request in the batch fails, exactly
like sequential pops).  Property tests (tests/test_properties.py) check the
no-double-allocation and conservation invariants under random
alloc/free interleavings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.packet import MTU, SMALL_SLOT

# Paper Table I: FPsPIN L2 packet memory = 512 KiB, split in half.
L2_PKT_BYTES = 512 * 1024
N_SMALL = (L2_PKT_BYTES // 2) // SMALL_SLOT          # 2048 slots
N_LARGE = (L2_PKT_BYTES // 2) // MTU                 # 170 slots
LARGE_BASE = N_SMALL * SMALL_SLOT                    # byte address of region


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AllocState:
    small_fifo: jax.Array   # (N_SMALL,) int32 slot ids
    small_head: jax.Array   # () int32
    small_count: jax.Array  # () int32
    large_fifo: jax.Array
    large_head: jax.Array
    large_count: jax.Array

    def tree_flatten(self):
        return (self.small_fifo, self.small_head, self.small_count,
                self.large_fifo, self.large_head, self.large_count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_state(n_small: int = N_SMALL, n_large: int = N_LARGE) -> AllocState:
    return AllocState(
        small_fifo=jnp.arange(n_small, dtype=jnp.int32),
        small_head=jnp.zeros((), jnp.int32),
        small_count=jnp.asarray(n_small, jnp.int32),
        large_fifo=jnp.arange(n_large, dtype=jnp.int32),
        large_head=jnp.zeros((), jnp.int32),
        large_count=jnp.asarray(n_large, jnp.int32),
    )


def _class_alloc(fifo, head, count, want):
    """Vectorized FIFO pop for one size class.

    want: (N,) bool.  Returns (fifo, head, count, slot, ok).
    """
    cap = fifo.shape[0]
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1          # pop order
    ok = want & (rank < count)
    pos = (head + jnp.maximum(rank, 0)) % cap
    slot = fifo[pos]
    taken = ok.sum().astype(jnp.int32)
    return (head + taken) % cap, count - taken, slot, ok


def alloc(state: AllocState, sizes: jax.Array, valid: jax.Array):
    """Allocate a slot per packet.  sizes (N,) int32, valid (N,) bool.

    Returns (state, addr (N,) int32, ok (N,) bool).  addr is the byte
    address within the L2 packet buffer; -1 when allocation failed (the
    packet is dropped — completion never arrives, exactly as in hardware
    when the free FIFO underflows).
    """
    is_small = sizes <= SMALL_SLOT
    sh, sc, s_slot, s_ok = _class_alloc(
        state.small_fifo, state.small_head, state.small_count,
        valid & is_small)
    lh, lc, l_slot, l_ok = _class_alloc(
        state.large_fifo, state.large_head, state.large_count,
        valid & ~is_small)
    addr = jnp.where(
        s_ok, s_slot * SMALL_SLOT,
        jnp.where(l_ok, LARGE_BASE + l_slot * MTU, -1)).astype(jnp.int32)
    new = AllocState(state.small_fifo, sh, sc, state.large_fifo, lh, lc)
    return new, addr, s_ok | l_ok


def _class_free(fifo, head, count, slot, do):
    cap = fifo.shape[0]
    rank = jnp.cumsum(do.astype(jnp.int32)) - 1
    tail = (head + count) % cap
    pos = jnp.where(do, (tail + rank) % cap, cap)           # cap -> dropped
    fifo = fifo.at[pos].set(slot, mode="drop")
    return fifo, count + do.sum().astype(jnp.int32)


def free(state: AllocState, addr: jax.Array, do: jax.Array) -> AllocState:
    """Return slots to their FIFOs.  addr (N,) int32, do (N,) bool."""
    do = do & (addr >= 0)
    is_small = addr < LARGE_BASE
    s_fifo, s_count = _class_free(
        state.small_fifo, state.small_head, state.small_count,
        addr // SMALL_SLOT, do & is_small)
    l_fifo, l_count = _class_free(
        state.large_fifo, state.large_head, state.large_count,
        (addr - LARGE_BASE) // MTU, do & ~is_small)
    return AllocState(s_fifo, state.small_head, s_count,
                      l_fifo, state.large_head, l_count)
