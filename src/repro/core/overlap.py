"""Compute/communication overlap engine — the paper's §V-C on TPU.

FPsPIN's headline result: offloaded MPI-datatype ingest overlaps ~96–98 %
with a host matrix multiplication (Fig 10, R = T_MM / (T_MM + T_Poll)).
The TPU-native equivalent: while train-step *t* computes, the sPIN ingest
for step *t+1* (match → SLMP reassembly → DDT unpack) is already in
flight.  Two mechanisms, both provided here:

* **Pipelined dispatch** (``overlapped_loop``): ingest and compute are
  separate jitted programs; JAX's asynchronous dispatch queues ingest for
  batch t+1 before blocking on compute t.  On TPU these run on independent
  device streams; the measured T_Poll is whatever the runtime could not
  hide.  This mirrors the paper's host-polling measurement exactly.
* **Fused step** (``fuse_ingest_into_step``): the ingest becomes part of
  the train-step XLA program, letting the scheduler interleave the unpack
  gathers with the first-layer compute (latency hiding by instruction
  scheduling rather than streams).

Both report the paper's metric:  R = T_MM / (T_MM + T_Poll).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, List, Tuple

import jax


@dataclasses.dataclass
class OverlapReport:
    steps: int
    t_mm_s: float          # time attributable to compute (blocked on it)
    t_poll_s: float        # extra time blocked waiting for ingest
    overlap_ratio: float   # R = T_MM / (T_MM + T_Poll)
    wall_s: float

    def row(self) -> str:
        return (f"steps={self.steps} t_mm={self.t_mm_s * 1e3:.2f}ms "
                f"t_poll={self.t_poll_s * 1e3:.2f}ms R={self.overlap_ratio:.4f}")


def _block(x) -> None:
    jax.block_until_ready(x)


def sequential_loop(ingest: Callable, compute: Callable, feeds: List,
                    state: Any) -> Tuple[Any, OverlapReport]:
    """No overlap: ingest batch t, wait, compute batch t, wait."""
    t_mm = t_poll = 0.0
    w0 = time.perf_counter()
    for feed in feeds:
        t0 = time.perf_counter()
        batch = ingest(feed)
        _block(batch)
        t1 = time.perf_counter()
        state = compute(state, batch)
        _block(state)
        t2 = time.perf_counter()
        t_poll += t1 - t0
        t_mm += t2 - t1
    wall = time.perf_counter() - w0
    r = t_mm / max(t_mm + t_poll, 1e-12)
    return state, OverlapReport(len(feeds), t_mm, t_poll, r, wall)


def overlapped_loop(ingest: Callable, compute: Callable, feeds: List,
                    state: Any) -> Tuple[Any, OverlapReport]:
    """Double-buffered: ingest t+1 is dispatched before blocking on
    compute t.  T_Poll counts only the time ingest was *not* hidden."""
    t_mm = t_poll = 0.0
    w0 = time.perf_counter()
    batch = ingest(feeds[0])           # prologue (unavoidable first fill)
    _block(batch)
    for i, feed in enumerate(feeds):
        state = compute(state, batch)              # async dispatch
        if i + 1 < len(feeds):
            nxt = ingest(feeds[i + 1])             # overlaps compute
        t0 = time.perf_counter()
        _block(state)                              # wait for compute
        t1 = time.perf_counter()
        if i + 1 < len(feeds):
            _block(nxt)                            # leftover ingest time
            batch = nxt
        t2 = time.perf_counter()
        t_mm += t1 - t0
        t_poll += t2 - t1
    wall = time.perf_counter() - w0
    r = t_mm / max(t_mm + t_poll, 1e-12)
    return state, OverlapReport(len(feeds), t_mm, t_poll, r, wall)


def fuse_ingest_into_step(ingest_fn: Callable, step_fn: Callable
                          ) -> Callable:
    """Return step'(state, raw_feed) = step(state, ingest(raw_feed)) as one
    XLA program (single jit).  Use with double buffering at the data level:
    the caller feeds raw packet tensors; XLA schedules the unpack gathers
    alongside the first matmuls."""

    def fused(state, raw_feed):
        return step_fn(state, ingest_fn(raw_feed))

    return jax.jit(fused, donate_argnums=(0,))
