"""The sPIN handler programming model and its vectorized execution VM.

A user of sPIN writes up to three functions — *header-*, *packet-* and
*tail-handler* (paper §III-A, §IV-C).  Here a handler is a pure JAX
function

    fn(args: HandlerArgs, user) -> HandlerOut

executed for every matching packet.  ``user`` is the per-context constant
state uploaded with the execution context (paper: handler code + host DMA
regions; here: any pytree — e.g. the DDT index map for datatype
processing).  The VM ``vmap``s the handler over the packet batch, so one
"HPU" is a vector lane; the handler-visible API mirrors Table IV:

    spin_send_packet   -> HandlerOut.egress_*
    spin_dma (to host) -> HandlerOut.dma_off / dma_val (byte-granular
                          scatter — this is the unaligned-write /
                          WSTRB-address-recovery path of pspin_hostmem_dma)
    spin_write_to_host -> write_u64_to_host helper
    push_counter       -> HandlerOut.counter_*
    cycles()           -> args.cycles
    spin_lock_*        -> intentionally absent: the vectorized VM applies
                          all effects by deterministic masked scatter, so
                          per-packet critical sections cannot race.  Message
                          state updates must be associative-commutative
                          (true concurrent-HPU programs need the same
                          discipline or locks).  See DESIGN.md §2.

Ordering semantics: the VM runs three phases per batch — header handlers,
then packet handlers, then tail handlers — and message state written by
the header phase is visible to the packet phase (sPIN guarantee).  Packet
handlers of one message run logically in parallel: their state updates are
accumulated by segment-sum.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.packet import MTU

MSG_STATE_DIM = 8        # int32 words of per-message handler state
N_COUNTER_QUEUES = 4
COUNTER_QUEUE_LEN = 64


class HandlerArgs(NamedTuple):
    """Per-packet arguments (the ``handler_args_t`` of the paper)."""
    pkt: jax.Array        # (MTU,) uint8 — packet bytes in L1/L2
    pkt_len: jax.Array    # () int32
    msg_id: jax.Array     # () uint32
    eom: jax.Array        # () bool
    ctx: jax.Array        # () int32
    msg_state: jax.Array  # (MSG_STATE_DIM,) int32
    cycles: jax.Array     # () int32 — global cycle counter (cycles())
    expect: jax.Array     # (E,) uint32 — host-programmed per-slot expected
    #                       msg_id table (shared across lanes): contexts
    #                       that reuse DMA regions check arriving frames
    #                       against it so a stale retransmit of a previous
    #                       occupant can never scribble a recycled slot


class HandlerOut(NamedTuple):
    """All effects a single handler invocation may produce."""
    egress_data: jax.Array   # (MTU,) uint8
    egress_len: jax.Array    # () int32
    egress_valid: jax.Array  # () bool
    dma_off: jax.Array       # (MTU,) int32 — host byte offsets, -1 = skip
    dma_val: jax.Array       # (MTU,) uint8
    state_delta: jax.Array   # (MSG_STATE_DIM,) int32 (associative add)
    counter_queue: jax.Array  # () int32, -1 = none
    counter_val: jax.Array    # () int32


def none_out() -> HandlerOut:
    return HandlerOut(
        egress_data=jnp.zeros((MTU,), jnp.uint8),
        egress_len=jnp.zeros((), jnp.int32),
        egress_valid=jnp.zeros((), bool),
        dma_off=jnp.full((MTU,), -1, jnp.int32),
        dma_val=jnp.zeros((MTU,), jnp.uint8),
        state_delta=jnp.zeros((MSG_STATE_DIM,), jnp.int32),
        counter_queue=jnp.full((), -1, jnp.int32),
        counter_val=jnp.zeros((), jnp.int32),
    )


# ----------------------------------------------------------------- runtime
def spin_send_packet(out: HandlerOut, data: jax.Array, length) -> HandlerOut:
    """Queue one egress packet (non-blocking send; paper spin_send_packet)."""
    return out._replace(egress_data=data,
                        egress_len=jnp.asarray(length, jnp.int32),
                        egress_valid=jnp.ones((), bool))


def spin_dma_to_host(out: HandlerOut, host_off, values: jax.Array,
                     nbytes, src_start=0) -> HandlerOut:
    """DMA ``values[src_start:src_start+nbytes]`` to host byte offset
    ``host_off``.  Byte-granular => arbitrarily unaligned, mirroring the
    unaligned-write support of pspin_hostmem_dma."""
    k = values.shape[0]
    lane = jnp.arange(k, dtype=jnp.int32)
    live = (lane >= src_start) & (lane < src_start + nbytes)
    off = jnp.where(live, host_off + (lane - src_start), -1).astype(jnp.int32)
    # merge with existing ops (first-writer wins on overlapping lanes)
    take = live & (out.dma_off[:k] < 0)
    new_off = out.dma_off.at[:k].set(
        jnp.where(take, off, out.dma_off[:k]))
    new_val = out.dma_val.at[:k].set(
        jnp.where(take, values, out.dma_val[:k]))
    return out._replace(dma_off=new_off, dma_val=new_val)


def spin_dma_scatter(out: HandlerOut, offsets: jax.Array, values: jax.Array
                     ) -> HandlerOut:
    """Fully general per-byte scatter DMA (offsets -1 = skip) — the DDT
    unpack path.  offsets/values are (MTU,) arrays."""
    return out._replace(dma_off=offsets.astype(jnp.int32), dma_val=values)


def write_u64_to_host(out: HandlerOut, host_off, value) -> HandlerOut:
    """spin_write_to_host: 64-bit little-endian word to host memory."""
    v = jnp.asarray(value, jnp.uint64)
    shifts = jnp.arange(8, dtype=jnp.uint64) * 8
    data = ((v >> shifts) & jnp.uint64(0xFF)).astype(jnp.uint8)
    return spin_dma_to_host(out, host_off, data, 8)


def push_counter(out: HandlerOut, queue: int, value) -> HandlerOut:
    """Enqueue a value into a host-readable FIFO (paper push_counter)."""
    return out._replace(counter_queue=jnp.asarray(queue, jnp.int32),
                        counter_val=jnp.asarray(value, jnp.int32))


def add_msg_state(out: HandlerOut, index: int, delta) -> HandlerOut:
    """Associative-commutative update of per-message state word ``index``."""
    return out._replace(
        state_delta=out.state_delta.at[index].add(
            jnp.asarray(delta, jnp.int32)))


HandlerFn = Callable[[HandlerArgs, Any], HandlerOut]


def default_handler(args: HandlerArgs, user: Any) -> HandlerOut:
    return none_out()


@dataclasses.dataclass
class ExecutionContext:
    """Host-side execution context: fpspin_init(ctx, ruleset, handlers)."""
    name: str
    ruleset: Any                          # matching.Ruleset
    header: HandlerFn = default_handler
    packet: HandlerFn = default_handler
    tail: HandlerFn = default_handler
    user: Any = None                      # constant pytree (device arrays)
    host_base: int = 0                    # base offset into host DMA buffer
    host_size: int = 0
    n_expect: int = 0                     # slots of the host-programmed
    #                                       expected-msg_id table this
    #                                       context owns (0 = unused)
    # message_mode=True: the protocol defines messages (header/tail handlers
    # run, MPQ tracks state).  False: pure packet matching (sPIN layer-2
    # mode — "simply execute the packet handler on every matching packet").
    message_mode: bool = False


_ARGS_AXES = HandlerArgs(pkt=0, pkt_len=0, msg_id=0, eom=0, ctx=0,
                         msg_state=0, cycles=0, expect=None)


def run_phase(fn: HandlerFn, args: HandlerArgs, user: Any,
              mask: jax.Array) -> HandlerOut:
    """vmap one handler over the batch and mask out non-participants
    (the expect table is shared, not per-lane)."""
    outs = jax.vmap(fn, in_axes=(_ARGS_AXES, None))(args, user)
    n = mask.shape[0]
    return HandlerOut(
        egress_data=outs.egress_data,
        egress_len=jnp.where(mask, outs.egress_len, 0),
        egress_valid=outs.egress_valid & mask,
        dma_off=jnp.where(mask[:, None], outs.dma_off, -1),
        dma_val=outs.dma_val,
        state_delta=jnp.where(mask[:, None], outs.state_delta, 0),
        counter_queue=jnp.where(mask, outs.counter_queue, -1),
        counter_val=jnp.where(mask, outs.counter_val, 0),
    )
