"""Handler Execution Requests and the packet scheduler (paper §III-C, §IV-4).

The ``pspin_her_gen`` module turns packet metadata (L2 address, size,
message id, EOM flag, matched context) into a HER; the packet scheduler
resolves the sPIN ordering dependencies — *header handlers are scheduled
before packet handlers, tail handlers after* — and fans tasks out to the
cluster schedulers / HPUs.

In the batched TPU model a ``HERBatch`` carries one record per packet and
the scheduler decides, per packet, whether the header handler must run
(first packet of a not-yet-active message) and assigns an HPU lane.  The
message-state table is the Message Processing Queue (MPQ) of the paper;
FPsPIN uses 16 entries (Table I) — we default to the same and hash
``(ctx, msg_id)`` into it.  An MPQ collision evicts the older message
(documented deviation: real PsPIN back-pressures instead; our tests size
the table to avoid collisions, and a counter records evictions so the
condition is observable).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

MPQ_ENTRIES = 16           # Table I (FPsPIN column)
N_CLUSTERS = 2             # Table I
HPUS_PER_CLUSTER = 8       # PsPIN cluster = 8 PULP cores
N_LANES = N_CLUSTERS * HPUS_PER_CLUSTER


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HERBatch:
    ctx: jax.Array       # (N,) int32  matched execution context (-1: none)
    addr: jax.Array      # (N,) int32  packet address in L2 buffer
    size: jax.Array      # (N,) int32  packet length in bytes
    msg_id: jax.Array    # (N,) uint32
    eom: jax.Array       # (N,) bool
    valid: jax.Array     # (N,) bool
    lane: jax.Array      # (N,) int32  assigned HPU lane
    slot: jax.Array      # (N,) int32  MPQ slot (message-state index)
    run_header: jax.Array  # (N,) bool
    run_tail: jax.Array    # (N,) bool

    def tree_flatten(self):
        return (self.ctx, self.addr, self.size, self.msg_id, self.eom,
                self.valid, self.lane, self.slot, self.run_header,
                self.run_tail), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MPQState:
    """Active-message table (the Message Processing Queue)."""
    key: jax.Array       # (S,) uint32 packed (ctx, msg_id) key
    active: jax.Array    # (S,) bool
    evictions: jax.Array  # () int32 — observability counter

    def tree_flatten(self):
        return (self.key, self.active, self.evictions), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_mpq(entries: int = MPQ_ENTRIES) -> MPQState:
    return MPQState(key=jnp.zeros((entries,), jnp.uint32),
                    active=jnp.zeros((entries,), bool),
                    evictions=jnp.zeros((), jnp.int32))


def _msg_key(ctx, msg_id):
    # pack context into the top 4 bits; contexts are few (<16)
    return (msg_id & jnp.uint32(0x0FFFFFFF)) | (
        ctx.astype(jnp.uint32) << 28)


def generate(mpq: MPQState, ctx, addr, size, msg_id, eom, valid,
             n_lanes: int = N_LANES):
    """HER generation + scheduling for one packet batch.

    Decides header/tail handler execution and updates the MPQ.  Returns
    (mpq, HERBatch).
    """
    n = ctx.shape[0]
    entries = mpq.key.shape[0]
    key = _msg_key(jnp.maximum(ctx, 0), msg_id)
    slot = (key % jnp.uint32(entries)).astype(jnp.int32)

    # first occurrence of each (ctx,msg) within this batch, in batch order
    same = (key[:, None] == key[None, :]) & valid[:, None] & valid[None, :]
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)
    first_in_batch = ~(same & earlier).any(axis=1)

    # message already active in the MPQ?
    mpq_hit = mpq.active[slot] & (mpq.key[slot] == key)
    run_header = valid & first_in_batch & ~mpq_hit
    run_tail = valid & eom

    # MPQ update: activate started messages, deactivate completed ones.
    # A slot collision (different key, slot active) evicts: count it.
    evict = run_header & mpq.active[slot] & (mpq.key[slot] != key)
    new_key = mpq.key.at[jnp.where(run_header, slot, entries)].set(
        key, mode="drop")
    new_active = mpq.active.at[jnp.where(run_header, slot, entries)].set(
        True, mode="drop")
    # EOM completes the message (tail handler runs in this batch)
    done = run_tail & (new_key[slot] == key)
    new_active = new_active.at[jnp.where(done, slot, entries)].set(
        False, mode="drop")
    new_mpq = MPQState(new_key, new_active,
                       mpq.evictions + evict.sum().astype(jnp.int32))

    # Lane assignment: cluster = slot parity (message affinity), round-robin
    # HPUs inside the cluster — mirrors the two-level scheduler.
    lane = (slot % N_CLUSTERS) * HPUS_PER_CLUSTER + (
        jnp.cumsum(valid.astype(jnp.int32)) - 1) % HPUS_PER_CLUSTER
    her = HERBatch(ctx=ctx, addr=addr, size=size, msg_id=msg_id, eom=eom,
                   valid=valid, lane=lane.astype(jnp.int32), slot=slot,
                   run_header=run_header, run_tail=run_tail)
    return new_mpq, her
