"""MPI Derived Datatypes and the dataloop engine (paper §V-C).

Supports the constructors the paper uses — ``MPI_Type_contiguous``,
``MPI_Type_vector``, ``MPI_Type_hvector`` — arbitrarily nested, plus
primitive types.  A datatype is *committed* by flattening it into the
serialization-ordered segment list ``[(mem_offset, length), ...]`` (the
MPICH dataloop representation) and then into **byte/element index maps**:

    msg_to_mem[k]  = memory byte offset of message byte k       (pack map)
    mem_to_msg[b]  = message position unpacked into memory byte b, -1=hole

This commit step is the *runtime code specialization* of Schneider et al.
[44] (which the paper names as the expected next optimization): instead of
interpreting the dataloop tree per byte on a 40 MHz HPU, the layout is
compiled once and (un)pack becomes a flat gather executed by the Pallas
kernel in :mod:`repro.kernels.ddt`.

Overlapping layouts (stride smaller than the block, paper Fig 9 "complex")
are supported: pack repeats the overlapped bytes; unpack applies message
bytes in serialization order, so the *last* occurrence wins — MPI's
sequential-unpack semantics.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


class DDT:
    """Base class. ``size`` = serialized bytes, ``extent`` = memory span."""
    size: int
    extent: int

    def _segments(self, base_off: int, out: List[Tuple[int, int]]) -> None:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Primitive(DDT):
    nbytes: int

    @property
    def size(self) -> int:
        return self.nbytes

    @property
    def extent(self) -> int:
        return self.nbytes

    def _segments(self, base_off, out):
        out.append((base_off, self.nbytes))


MPI_FLOAT = Primitive(4)
MPI_DOUBLE = Primitive(8)
MPI_INT = Primitive(4)
MPI_BYTE = Primitive(1)


@dataclasses.dataclass(frozen=True)
class Contiguous(DDT):
    count: int
    base: DDT

    @property
    def size(self):
        return self.count * self.base.size

    @property
    def extent(self):
        return self.count * self.base.extent

    def _segments(self, base_off, out):
        for i in range(self.count):
            self.base._segments(base_off + i * self.base.extent, out)


@dataclasses.dataclass(frozen=True)
class Vector(DDT):
    """count blocks of blocklen base elements, stride in base-extents."""
    count: int
    blocklen: int
    stride: int
    base: DDT

    @property
    def size(self):
        return self.count * self.blocklen * self.base.size

    @property
    def extent(self):
        if self.count == 0:
            return 0
        return ((self.count - 1) * self.stride + self.blocklen) \
            * self.base.extent

    def _segments(self, base_off, out):
        for i in range(self.count):
            for j in range(self.blocklen):
                self.base._segments(
                    base_off + (i * self.stride + j) * self.base.extent, out)


@dataclasses.dataclass(frozen=True)
class HVector(DDT):
    """Like Vector but the stride is given in bytes (MPI_Type_hvector)."""
    count: int
    blocklen: int
    stride_bytes: int
    base: DDT

    @property
    def size(self):
        return self.count * self.blocklen * self.base.size

    @property
    def extent(self):
        if self.count == 0:
            return 0
        return (self.count - 1) * self.stride_bytes \
            + self.blocklen * self.base.extent

    def _segments(self, base_off, out):
        for i in range(self.count):
            for j in range(self.blocklen):
                self.base._segments(
                    base_off + i * self.stride_bytes + j * self.base.extent,
                    out)


# ------------------------------------------------------------------ commit
def segments(ddt: DDT, count: int = 1) -> List[Tuple[int, int]]:
    """Flatten ``count`` instances into merged (offset, length) segments in
    serialization order (the dataloop contig-merge optimization)."""
    raw: List[Tuple[int, int]] = []
    for i in range(count):
        ddt._segments(i * ddt.extent, raw)
    merged: List[Tuple[int, int]] = []
    for off, ln in raw:
        if merged and merged[-1][0] + merged[-1][1] == off:
            merged[-1] = (merged[-1][0], merged[-1][1] + ln)
        else:
            merged.append((off, ln))
    return merged


@dataclasses.dataclass(frozen=True)
class CommittedDDT:
    """Index-map ("specialized") form of `count` instances of a datatype."""
    ddt: DDT
    count: int
    msg_bytes: int                 # serialized message size
    mem_bytes: int                 # memory extent covered
    msg_to_mem: np.ndarray         # (msg_bytes,) int32
    mem_to_msg: np.ndarray         # (mem_bytes,) int32, -1 = hole
    n_segments: int


def commit(ddt: DDT, count: int = 1) -> CommittedDDT:
    segs = segments(ddt, count)
    msg_bytes = ddt.size * count
    mem_bytes = ddt.extent * count
    msg_to_mem = np.empty(msg_bytes, np.int32)
    k = 0
    for off, ln in segs:
        msg_to_mem[k:k + ln] = np.arange(off, off + ln, dtype=np.int32)
        k += ln
    assert k == msg_bytes, (k, msg_bytes)
    mem_to_msg = np.full(mem_bytes, -1, np.int32)
    # serialization order: later message bytes overwrite earlier on overlap
    mem_to_msg[msg_to_mem] = np.arange(msg_bytes, dtype=np.int32)
    return CommittedDDT(ddt=ddt, count=count, msg_bytes=msg_bytes,
                        mem_bytes=mem_bytes, msg_to_mem=msg_to_mem,
                        mem_to_msg=mem_to_msg, n_segments=len(segs))


def element_maps(c: CommittedDDT, elem_bytes: int = 4):
    """Element-granular maps (all offsets must be elem-aligned) for the
    Pallas kernel fast path.  Returns (pack_idx, unpack_idx) int32 arrays:
    message[i] = mem[pack_idx[i]];  mem[j] = message[unpack_idx[j]] | hole.
    """
    if c.msg_bytes % elem_bytes or c.mem_bytes % elem_bytes:
        raise ValueError("size not element-aligned")
    m2m = c.msg_to_mem.reshape(-1, elem_bytes)
    if (np.diff(m2m, axis=1) != 1).any() or (m2m[:, 0] % elem_bytes).any():
        raise ValueError("layout not element-aligned")
    pack_idx = (m2m[:, 0] // elem_bytes).astype(np.int32)
    unpack = c.mem_to_msg.reshape(-1, elem_bytes)
    first = unpack[:, 0]
    unpack_idx = np.where(first >= 0, first // elem_bytes, -1).astype(np.int32)
    return pack_idx, unpack_idx


# ------------------------------------------------------- paper Fig 9 types
def simple_ddt() -> DDT:
    """Fig 9 "simple": a strided vector of float pairs (gaps, no overlap)."""
    return Vector(count=8, blocklen=2, stride=4, base=MPI_FLOAT)


def complex_ddt() -> DDT:
    """Fig 9 "complex": nested vector-of-vectors with overlapping blocks
    (outer hvector stride < inner extent => data repeats in the message)."""
    inner = Vector(count=2, blocklen=3, stride=4, base=MPI_FLOAT)
    return HVector(count=5, blocklen=1, stride_bytes=16, base=inner)


# ------------------------------------------------------------ numpy oracle
def pack_np(c: CommittedDDT, mem: np.ndarray) -> np.ndarray:
    """Serialize: message bytes gathered from memory (numpy oracle)."""
    return mem[c.msg_to_mem]


def unpack_np(c: CommittedDDT, msg: np.ndarray, mem: np.ndarray
              ) -> np.ndarray:
    """De-serialize in serialization order (last write wins on overlap)."""
    out = mem.copy()
    out[c.msg_to_mem] = msg
    return out
