"""The complete FPsPIN datapath (paper Fig 5) as one jitted device program.

One ``step`` processes a batch of ingress frames through the exact module
sequence of the hardware:

  1. ``pspin_pkt_match``   — execution-context matching (kernels/matcher);
                              non-matching frames are *forwarded to the
                              Corundum/host datapath* (returned unmodified).
  2. ``pspin_pkt_alloc``   — bimodal slot allocation in the L2 packet
                              buffer (core/alloc); on FIFO underflow the
                              frame is dropped and counted.
  3. ``pspin_ingress_dma`` — frames are DMA'd into the modelled L2 packet
                              buffer (a real (512 KiB,) uint8 array — the
                              handlers read their packet bytes back out of
                              it, like HPUs reading L1/L2).
  4. ``pspin_her_gen``     — HER generation + MPQ scheduling (core/her).
  5. handler execution     — header → packet → tail phases (core/handlers),
                              message state visible across phases.
  6. effect application    — ``pspin_egress_dma`` (handler sends are
                              arbitrated into one egress batch),
                              ``pspin_hostmem_dma`` (byte-granular,
                              unaligned-capable scatter into host memory),
                              counter FIFOs, completion notifications
                              (slot free).

Everything is a pure function of ``NICState`` — checkpointable, jittable,
and shardable (the packet axis shards over the data mesh axis).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alloc as palloc
from repro.core import handlers as H
from repro.core import her as herlib
from repro.core import matching
from repro.core import packet as pkt


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NICState:
    l2: jax.Array            # (L2_PKT_BYTES,) uint8 packet buffer
    alloc: palloc.AllocState
    mpq: herlib.MPQState
    msg_state: jax.Array     # (MPQ, MSG_STATE_DIM) int32
    host: jax.Array          # (HOST,) uint8 — host DMA window
    counters: jax.Array      # (Q, QLEN) int32
    counter_count: jax.Array  # (Q,) int32
    cycles: jax.Array        # () int32
    dropped: jax.Array       # () int32 — alloc-failure drops
    expect: jax.Array        # (E,) uint32 — host-programmed per-slot
    #                          expected msg_id (0 = slot disarmed); the
    #                          MMIO analogue of posting a receive to the
    #                          NIC before granting the sender a CTS

    def tree_flatten(self):
        return (self.l2, self.alloc, self.mpq, self.msg_state, self.host,
                self.counters, self.counter_count, self.cycles,
                self.dropped, self.expect), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _select_out(acc: H.HandlerOut, new: H.HandlerOut, mask) -> H.HandlerOut:
    m1 = mask[:, None]
    return H.HandlerOut(
        egress_data=jnp.where(m1, new.egress_data, acc.egress_data),
        egress_len=jnp.where(mask, new.egress_len, acc.egress_len),
        egress_valid=jnp.where(mask, new.egress_valid, acc.egress_valid),
        dma_off=jnp.where(m1, new.dma_off, acc.dma_off),
        dma_val=jnp.where(m1, new.dma_val, acc.dma_val),
        state_delta=jnp.where(m1, new.state_delta, acc.state_delta),
        counter_queue=jnp.where(mask, new.counter_queue, acc.counter_queue),
        counter_val=jnp.where(mask, new.counter_val, acc.counter_val),
    )


class SpinNIC:
    """Host-side object holding installed execution contexts (fpspin_init)."""

    def __init__(self, contexts: List[H.ExecutionContext],
                 host_bytes: int = 1 << 20, batch: int = 64,
                 use_kernels: bool = False,
                 mpq_entries: int = herlib.MPQ_ENTRIES):
        assert len(contexts) >= 1
        self.contexts = contexts
        self.host_bytes = host_bytes
        self.batch = batch
        self.use_kernels = use_kernels
        self.mpq_entries = mpq_entries
        self.tables = matching.MatchTables.build(
            [c.ruleset for c in contexts])
        # the expect table currently has a single flat slot space indexed
        # from 0: exactly one context may own it (per-context base offsets
        # would be needed for more — assert rather than silently alias)
        assert sum(1 for c in contexts if c.n_expect > 0) <= 1, \
            "only one execution context may use the expect table"
        self._msgful = jnp.asarray(
            np.array([c.message_mode for c in contexts], bool))
        self._host_base = jnp.asarray(
            np.array([c.host_base for c in contexts], np.int32))
        self._step = jax.jit(self._step_impl, donate_argnums=(0,))

    # -------------------------------------------------------------- state
    def init_state(self) -> NICState:
        return NICState(
            l2=jnp.zeros((palloc.L2_PKT_BYTES,), jnp.uint8),
            alloc=palloc.make_state(),
            mpq=herlib.make_mpq(self.mpq_entries),
            msg_state=jnp.zeros((self.mpq_entries, H.MSG_STATE_DIM),
                                jnp.int32),
            host=jnp.zeros((self.host_bytes,), jnp.uint8),
            counters=jnp.zeros((H.N_COUNTER_QUEUES, H.COUNTER_QUEUE_LEN),
                               jnp.int32),
            counter_count=jnp.zeros((H.N_COUNTER_QUEUES,), jnp.int32),
            cycles=jnp.zeros((), jnp.int32),
            dropped=jnp.zeros((), jnp.int32),
            expect=jnp.zeros(
                (max(1, sum(c.n_expect for c in self.contexts)),),
                jnp.uint32),
        )

    # --------------------------------------------------------------- step
    def step(self, state: NICState, batch: pkt.PacketBatch
             ) -> Tuple[NICState, pkt.PacketBatch, pkt.PacketBatch]:
        """Process one ingress batch.

        Returns (state, egress_batch, to_host_batch): egress = handler
        sends; to_host = non-matching frames forwarded to the standard NIC
        datapath (ARP passthrough & friends, paper §IV).
        """
        return self._step(state, batch)

    def _step_impl(self, state: NICState, batch: pkt.PacketBatch):
        n = batch.n
        byte_iota = jnp.arange(pkt.MTU, dtype=jnp.int32)

        # (1) matching engine
        ctx_id, eom = matching.match_batch(batch, self.tables,
                                           use_kernel=self.use_kernels)
        process = batch.valid & (ctx_id >= 0)
        to_host = pkt.PacketBatch(batch.data, batch.length,
                                  batch.valid & (ctx_id < 0))

        # (2) allocator
        alloc_state, addr, ok = palloc.alloc(state.alloc, batch.length,
                                             process)
        dropped = state.dropped + (process & ~ok).sum().astype(jnp.int32)
        live = process & ok

        # (3) ingress DMA into the L2 packet buffer.  Frames land at
        # contiguous slot addresses, so this is a masked read-modify-write
        # of one MTU window per lane (dynamic_update_slice), not a
        # per-byte scatter — XLA:CPU executes scatters element-by-element,
        # and this loop is ~10x cheaper than the equivalent flat scatter.
        # Slot geometry guarantees addr + MTU <= L2_PKT_BYTES (large slots
        # are MTU-sized and the region ends on a slot boundary).
        def _dma_in(i, l2):
            a = addr[i]
            window = jax.lax.dynamic_slice(l2, (a,), (pkt.MTU,))
            keep = live[i] & (byte_iota < batch.length[i])
            return jax.lax.dynamic_update_slice(
                l2, jnp.where(keep, batch.data[i], window), (a,))

        l2 = jax.lax.cond(
            live.any(),
            lambda l2: jax.lax.fori_loop(0, n, _dma_in, l2),
            lambda l2: l2, state.l2)

        # (4) HER generation + scheduling (message-mode contexts only track
        #     MPQ state; packet-mode contexts always run packet handlers)
        msgful = self._msgful[jnp.maximum(ctx_id, 0)] & live
        msg_id = pkt.read_u32(batch.data, pkt.SLMP_MSGID)
        mpq, her = herlib.generate(state.mpq, ctx_id, addr, batch.length,
                                   msg_id, eom & msgful, msgful)
        run_header = her.run_header & msgful
        run_tail = her.run_tail & msgful

        # (5) handler execution: read packet bytes back from L2
        gather_off = jnp.where(
            live[:, None], addr[:, None] + byte_iota[None, :], 0)
        pkt_view = jnp.where(live[:, None], l2[gather_off], 0)

        def make_args(msg_state):
            return H.HandlerArgs(
                pkt=pkt_view, pkt_len=batch.length, msg_id=msg_id,
                eom=eom, ctx=ctx_id,
                msg_state=msg_state[her.slot],
                cycles=jnp.broadcast_to(state.cycles, (n,)),
                expect=state.expect)

        msg_state = state.msg_state
        phase_outs = []
        for phase, phase_mask in (("header", run_header),
                                  ("packet", live),
                                  ("tail", run_tail)):
            args = make_args(msg_state)
            acc = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape),
                               H.none_out())
            for c, ectx in enumerate(self.contexts):
                fn = getattr(ectx, phase)
                if fn is H.default_handler:
                    continue
                mask = phase_mask & (ctx_id == c)
                out = H.run_phase(fn, args, ectx.user, mask)
                acc = _select_out(acc, out, mask)
            # message state becomes visible to the next phase
            msg_state = msg_state.at[her.slot].add(
                jnp.where(phase_mask[:, None], acc.state_delta, 0))
            phase_outs.append(acc)

        # (6a) host DMA: byte-granular scatter (unaligned-capable).  Each
        # phase's scatter runs under a cond so phases that DMA'd nothing
        # this batch (header/tail on most traffic, every phase on ACK-only
        # batches) skip the expensive CPU scatter entirely.
        host = state.host
        base = self._host_base[jnp.maximum(ctx_id, 0)]
        for out in phase_outs:
            off = jnp.where(out.dma_off >= 0,
                            base[:, None] + out.dma_off,
                            self.host_bytes)           # OOB -> dropped
            host = jax.lax.cond(
                (out.dma_off >= 0).any(),
                lambda h, o=off, v=out.dma_val: h.at[o.reshape(-1)].set(
                    v.reshape(-1), mode="drop"),
                lambda h: h, host)

        # (6b) egress arbitration (axis_arb_mux): compact all sends
        eg_data = jnp.concatenate([o.egress_data for o in phase_outs])
        eg_len = jnp.concatenate([o.egress_len for o in phase_outs])
        eg_valid = jnp.concatenate([o.egress_valid for o in phase_outs])
        order = jnp.argsort(~eg_valid, stable=True)[:n]
        egress = pkt.PacketBatch(eg_data[order], eg_len[order],
                                 eg_valid[order])

        # (6c) counter FIFOs (cond-gated: most phases push no counters)
        counters, counter_count = state.counters, state.counter_count
        for out in phase_outs:
            def _push_counters(cc, out=out):
                counters, counter_count = cc
                for q in range(H.N_COUNTER_QUEUES):
                    sel = out.counter_queue == q
                    rank = jnp.cumsum(sel.astype(jnp.int32)) - 1
                    pos = jnp.where(sel,
                                    (counter_count[q] + rank)
                                    % H.COUNTER_QUEUE_LEN,
                                    H.COUNTER_QUEUE_LEN)
                    counters = counters.at[q, pos].set(out.counter_val,
                                                       mode="drop")
                    counter_count = counter_count.at[q].add(
                        sel.sum().astype(jnp.int32))
                return counters, counter_count

            counters, counter_count = jax.lax.cond(
                (out.counter_queue >= 0).any(), _push_counters,
                lambda cc: cc, (counters, counter_count))

        # (6d) completion notification -> free packet-buffer slots
        alloc_state = palloc.free(alloc_state, addr, live)

        new_state = NICState(
            l2=l2, alloc=alloc_state, mpq=mpq, msg_state=msg_state,
            host=host, counters=counters, counter_count=counter_count,
            cycles=state.cycles + 1, dropped=dropped, expect=state.expect)
        return new_state, egress, to_host

    # ------------------------------------------------------------- host API
    def write_expect(self, state: NICState, idx: int,
                     msg_id: int) -> NICState:
        """Host MMIO: arm (or disarm, msg_id=0) one slot of the expected
        msg_id table — the host posts the receive to the NIC *before*
        telling the sender to fire, so a recycled DMA region only accepts
        frames of its current occupant."""
        return dataclasses.replace(
            state, expect=state.expect.at[idx].set(
                jnp.uint32(msg_id)))

    def read_host(self, state: NICState, base: int, nbytes: int
                  ) -> np.ndarray:
        """Host read of the DMA window (the /dev/pspin0 mmap view)."""
        return np.asarray(state.host[base:base + nbytes])

    def pop_counters(self, state: NICState, queue: int
                     ) -> Tuple[np.ndarray, NICState]:
        """Drain a counter FIFO (host side).

        Returns ``(values, state)`` where the returned state has the queue
        count cleared — a second pop yields nothing until handlers push
        again (a real FIFO drain, not a peek).
        """
        cnt = int(state.counter_count[queue])
        if cnt == 0:
            # nothing pushed since the last drain: skip the device
            # round-trips (this runs after every non-idle fabric tick)
            return np.zeros(0, np.int32), state
        vals = np.asarray(state.counters[queue])
        start = max(0, cnt - H.COUNTER_QUEUE_LEN)   # older entries overwritten
        drained = np.array([vals[(start + i) % H.COUNTER_QUEUE_LEN]
                            for i in range(cnt - start)], np.int32)
        new_state = dataclasses.replace(
            state, counter_count=state.counter_count.at[queue].set(0))
        return drained, new_state
