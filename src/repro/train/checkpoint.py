"""Checkpointing: atomic, manifest-based, elastic-reshard-capable.

Layout:  <dir>/step-<N>/<leaf-id>.npy + manifest.json, written to a temp
dir and atomically renamed (a crash mid-save never corrupts the latest
checkpoint); <dir>/LATEST names the newest complete step.

Restore takes a *template* pytree (shapes/dtypes from ``model.init`` via
``jax.eval_shape``) and an optional shardings pytree: leaves are loaded
with numpy and ``jax.device_put`` onto the target sharding — the target
mesh does not need to match the mesh that wrote the checkpoint, which is
the elastic-rescale path (N pods -> M pods just changes the shardings).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, tag: str = "state") -> str:
    """Atomic save.  Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp-ckpt-", dir=ckpt_dir)
    manifest = {"step": step, "tag": tag, "leaves": []}
    try:
        for i, (name, leaf) in enumerate(_leaves_with_paths(tree)):
            arr = np.asarray(leaf)
            shape = list(arr.shape)            # before ascontiguousarray
            arr = np.ascontiguousarray(arr)    # (promotes 0-d to 1-d)
            fn = f"leaf-{i:05d}.npy"
            # bfloat16 etc. are not numpy-native: persist raw bytes and
            # record the true dtype in the manifest
            np.save(os.path.join(tmp, fn),
                    arr.view(np.uint8).reshape(-1))
            manifest["leaves"].append(
                {"name": name, "file": fn, "shape": shape,
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        step = int(f.read().strip())
    if os.path.exists(os.path.join(ckpt_dir, f"step-{step:08d}",
                                   "manifest.json")):
        return step
    return None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Load a checkpoint into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding, same structure)
    re-places every leaf on the current mesh — elastic rescale."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step-{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {m["name"]: m for m in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), shard in zip(flat, shard_leaves):
        name = jax.tree_util.keystr(path)
        m = by_name[name]
        raw = np.load(os.path.join(d, m["file"]))
        try:
            dt = np.dtype(m["dtype"])
        except TypeError:
            import ml_dtypes
            dt = np.dtype(getattr(ml_dtypes, m["dtype"]))
        arr = raw.view(dt).reshape(m["shape"])
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"{name}: ckpt {arr.shape} != {expect}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
