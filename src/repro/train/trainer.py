"""Trainer: jitted train step (loss → grads → clip → AdamW), microbatch
accumulation, sPIN-ingest overlap, checkpoint/restart, straggler watchdog.

The step function is built once per (model, mesh, flags):

  * mesh=None  — single-device path (CPU examples/tests);
  * mesh given — pjit with parameter/optimizer/batch shardings from
    parallel/sharding.py (this is also exactly what launch/dryrun.py
    lowers for the 40 assigned cells);
  * microbatches > 1 — ``lax.scan`` gradient accumulation inside the step
    (global batch stays the assigned size; activation memory drops by the
    microbatch factor);
  * grad_compression — int8 error-feedback all-reduce over the data axes
    (parallel/compression.py) in manual-DP mode.

Fault tolerance: ``fit`` checkpoints every ``ckpt_every`` steps (atomic,
elastic-reshardable — train/checkpoint.py), resumes from LATEST on
restart, and a watchdog flags straggler steps (> ``straggler_factor`` ×
running median) — the single-process stand-in for the per-worker heartbeat
a multi-host deployment wires into the same hook.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.parallel import sharding as shlib
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    microbatches: int = 1
    log_every: int = 10
    ckpt_every: int = 0                 # 0 = disabled
    ckpt_dir: str = "/tmp/repro-ckpt"
    straggler_factor: float = 3.0
    donate: bool = True
    fsdp: bool = False


class Trainer:
    def __init__(self, model: Model, opt_cfg: opt.OptConfig,
                 tcfg: TrainerConfig, mesh=None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self._step_fn = None
        self.straggler_events = []

    # ------------------------------------------------------------ stepfn
    def build_step(self, batch_example=None) -> Callable:
        model, ocfg, tcfg = self.model, self.opt_cfg, self.tcfg

        def loss_fn(params, batch):
            loss, metrics = model.loss_fn(params, batch)
            return loss, metrics

        def step(params, opt_state, batch):
            if tcfg.microbatches > 1:
                def split(x):
                    b = x.shape[0]
                    mb = tcfg.microbatches
                    return x.reshape(mb, b // mb, *x.shape[1:])
                # M-RoPE positions carry batch on dim 1
                mbatch = {}
                for k, v in batch.items():
                    if k == "positions":
                        mb = tcfg.microbatches
                        mbatch[k] = jnp.moveaxis(
                            v.reshape(3, mb, v.shape[1] // mb, -1), 1, 0)
                    else:
                        mbatch[k] = split(v)

                def mb_step(acc, mb):
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    acc_g, acc_l = acc
                    acc_g = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32),
                        acc_g, grads)
                    return (acc_g, acc_l + loss), metrics

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss_sum), metrics = jax.lax.scan(
                    mb_step, (zeros, jnp.zeros((), jnp.float32)), mbatch)
                grads = jax.tree.map(
                    lambda g: g / tcfg.microbatches, grads)
                loss = loss_sum / tcfg.microbatches
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)

            params2, opt_state2, om = opt.apply_updates(
                params, opt_state, grads, ocfg)
            metrics = dict(metrics, loss=loss, **om)
            return params2, opt_state2, metrics

        donate = (0, 1) if tcfg.donate else ()
        if self.mesh is None:
            self._step_fn = jax.jit(step, donate_argnums=donate)
        else:
            cfg = model.cfg
            pshape = model.init_eval()
            pshard = shlib.param_shardings(pshape, cfg, self.mesh,
                                           fsdp=tcfg.fsdp)
            oshape = jax.eval_shape(opt.init, pshape)
            oshard = opt.OptState(mu=pshard, nu=pshard,
                                  step=shlib.replicated(self.mesh))
            in_sh = (pshard, oshard)
            if batch_example is not None:
                in_sh = in_sh + (shlib.batch_shardings(batch_example,
                                                       self.mesh),)
                self._step_fn = jax.jit(
                    step, donate_argnums=donate,
                    in_shardings=in_sh,
                    out_shardings=(pshard, oshard, None))
            else:
                self._step_fn = jax.jit(step, donate_argnums=donate)
        return self._step_fn

    # -------------------------------------------------------------- fit
    def fit(self, params, opt_state, batches: Iterator,
            start_step: int = 0, resume: bool = True):
        """Run the training loop.  Returns (params, opt_state, history)."""
        tcfg = self.tcfg
        if self._step_fn is None:
            self.build_step()
        step_fn = self._step_fn

        if resume and tcfg.ckpt_every:
            last = ckpt.latest_step(tcfg.ckpt_dir)
            if last is not None and last > start_step:
                (params, opt_state), _ = ckpt.restore(
                    tcfg.ckpt_dir, (params, opt_state), step=last)
                start_step = last

        history = []
        durations = []
        t_step = start_step
        for batch in batches:
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations[-32:]))
            if len(durations) > 4 and dt > tcfg.straggler_factor * med:
                self.straggler_events.append((t_step, dt, med))
            t_step += 1
            if tcfg.log_every and t_step % tcfg.log_every == 0:
                history.append({"step": t_step,
                                "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "sec_per_step": dt})
            if tcfg.ckpt_every and t_step % tcfg.ckpt_every == 0:
                ckpt.save(tcfg.ckpt_dir, t_step, (params, opt_state))
            if t_step - start_step >= tcfg.steps:
                break
        return params, opt_state, history
