"""Packetized training-data pipeline — the paper's §V-C as a data layer.

The training corpus arrives the way FPsPIN receives it: as **SLMP messages
whose payloads are MPI-DDT-packed tensors**.  The pipeline has two halves:

* host half (this module, numpy + background thread): synthesizes the
  token stream, lays it out in a non-contiguous "application buffer"
  described by an MPI datatype, packs it (sender side), segments it into
  SLMP frames, and hands raw packet tensors to the device;
* device half (``SpinIngest``): one jitted program running
  match → SLMP offset parsing → DDT unpack (the committed index-map
  gather) → token batch, fused or double-buffered against the train step
  (core/overlap.py).

The synthetic corpus is a deterministic PRNG token stream with a bigram
structure (so training loss measurably drops — used by the end-to-end
example and convergence tests).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddt as ddtlib
from repro.core import matching
from repro.core import packet as pkt
from repro.kernels.ddt import ops as ddt_ops


# --------------------------------------------------------- synthetic corpus
@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic bigram-ish token stream (learnable structure)."""
    vocab: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # each token deterministically prefers a successor: t -> perm[t]
        self.perm = rng.permutation(self.vocab)

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        first = rng.integers(0, self.vocab, size=(batch, 1))
        toks = [first]
        cur = first
        for _ in range(seq):
            follow = self.perm[cur]
            noise = rng.integers(0, self.vocab, size=cur.shape)
            use_noise = rng.random(cur.shape) < 0.25
            cur = np.where(use_noise, noise, follow)
            toks.append(cur)
        full = np.concatenate(toks, axis=1)          # (B, seq+1)
        return full.astype(np.int32)


# ---------------------------------------------------------- sender (host)
@dataclasses.dataclass
class PacketizedBatch:
    """Raw packet tensors for one training batch (device-ready)."""
    data: np.ndarray       # (n_packets, MTU) uint8
    length: np.ndarray     # (n_packets,) int32
    valid: np.ndarray      # (n_packets,) bool
    tokens_shape: Tuple[int, int]


def _batch_ddt(nbytes: int) -> ddtlib.DDT:
    """The datatype describing the application's strided batch layout:
    a vector of 256-byte blocks with 64-byte gaps (a typical row-strided
    array section).  nbytes must be a multiple of 256."""
    assert nbytes % 256 == 0
    return ddtlib.Vector(count=nbytes // 256, blocklen=64, stride=80,
                         base=ddtlib.MPI_FLOAT)


class PacketizedPipeline:
    """Host half: corpus -> DDT pack -> SLMP segments -> packet tensors."""

    def __init__(self, vocab: int, batch: int, seq: int, port: int = 9332,
                 seed: int = 0, payload: int = pkt.MAX_SLMP_PAYLOAD):
        self.corpus = SyntheticCorpus(vocab, seed)
        self.batch, self.seq = batch, seq
        self.port = port
        self.payload = payload
        msg_bytes = batch * (seq + 1) * 4
        pad = (-msg_bytes) % 256
        self.msg_bytes = msg_bytes + pad
        self.ddt = _batch_ddt(self.msg_bytes)
        self.committed = ddtlib.commit(self.ddt, count=1)
        self.n_packets = (self.msg_bytes + payload - 1) // payload
        # device-side unpack index map (element granular, 4-byte tokens)
        pack_idx, unpack_idx = ddtlib.element_maps(self.committed, 4)
        self.pack_idx = pack_idx            # msg elem -> mem elem
        self.unpack_idx = unpack_idx        # mem elem -> msg elem
        self.mem_elems = self.committed.mem_bytes // 4

    def packets_for_step(self, step: int) -> PacketizedBatch:
        toks = self.corpus.batch(step, self.batch, self.seq)   # (B, S+1)
        flat = np.zeros(self.msg_bytes // 4, np.int32)
        flat[: toks.size] = toks.reshape(-1)
        # application buffer: tokens scattered at their DDT memory offsets
        mem = np.zeros(self.mem_elems, np.int32)
        mem[self.pack_idx] = flat                              # app layout
        # sender-side pack (serialization) — gather by the pack map
        message = mem[self.pack_idx].view(np.uint8)            # packed msg
        frames = []
        for s in range(self.n_packets):
            off = s * self.payload
            seg = message[off: off + self.payload]
            flags = pkt.SLMP_FLAG_EOM if s == self.n_packets - 1 else 0
            frames.append(pkt.make_slmp(step & 0x0FFFFFFF, off, flags,
                                        np.asarray(seg), dport=self.port))
        b = pkt.stack_frames(frames, n=self.n_packets)
        return PacketizedBatch(np.asarray(b.data), np.asarray(b.length),
                               np.asarray(b.valid), toks.shape)


# --------------------------------------------------------- device ingest
class SpinIngest:
    """Device half: one jitted program, packets -> token batch.

    This is the sPIN offload: U32 match (SLMP ruleset), per-packet offset
    parse, payload scatter into the message buffer (SLMP reassembly), then
    the committed-DDT unpack gather (kernels/ddt) and token reshape.
    """

    def __init__(self, pipeline: PacketizedPipeline,
                 use_kernels: bool = False):
        self.pl = pipeline
        self.tables = matching.MatchTables.build(
            [matching.ruleset_slmp(pipeline.port)])
        self.use_kernels = use_kernels
        self._fn = jax.jit(self._ingest)

    def _ingest(self, data, length, valid):
        pl = self.pl
        batch = pkt.PacketBatch(data, length, valid)
        ctx, _eom = matching.match_batch(batch, self.tables,
                                         use_kernel=self.use_kernels)
        live = valid & (ctx == 0)
        offsets = pkt.read_u32(data, pkt.SLMP_OFFSET).astype(jnp.int32)
        plen = length - pkt.SLMP_PAYLOAD
        lane = jnp.arange(pkt.MTU, dtype=jnp.int32)
        msg_pos = offsets[:, None] + (lane - pkt.SLMP_PAYLOAD)[None, :]
        ok = live[:, None] & (lane >= pkt.SLMP_PAYLOAD)[None, :] \
            & ((lane - pkt.SLMP_PAYLOAD) < plen[:, None])
        dst = jnp.where(ok, msg_pos, pl.msg_bytes)
        msg = jnp.zeros((pl.msg_bytes,), jnp.uint8)
        msg = msg.at[dst.reshape(-1)].set(data.reshape(-1), mode="drop")
        # receiver-side app buffer = DDT unpack of the message
        msg_elems = jax.lax.bitcast_convert_type(
            msg.reshape(-1, 4), jnp.int32).reshape(-1)
        mem = ddt_ops.gather(msg_elems,
                             jnp.asarray(pl.unpack_idx),
                             use_kernel=self.use_kernels)
        # tokens live at the DDT's mapped offsets: gather them back out
        toks = ddt_ops.gather(mem, jnp.asarray(pl.pack_idx),
                              use_kernel=self.use_kernels)
        b, s1 = pl.batch, pl.seq + 1
        toks = toks[: b * s1].reshape(b, s1)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __call__(self, raw: PacketizedBatch) -> Dict[str, jax.Array]:
        return self._fn(jnp.asarray(raw.data), jnp.asarray(raw.length),
                        jnp.asarray(raw.valid))


def prefetch_iterator(pipeline: PacketizedPipeline, steps: int,
                      depth: int = 2) -> Iterator[PacketizedBatch]:
    """Background-thread host prefetch (overlaps packet synthesis with
    device compute — the host half of the paper's overlap story)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        for i in range(steps):
            q.put(pipeline.packets_for_step(i))
        q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
