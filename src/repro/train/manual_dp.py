"""Manual-DP train step: explicit (compressed) data-parallel gradient
reduction via partial-auto ``shard_map``.

Under plain pjit, XLA inserts the data-parallel gradient all-reduce
itself (bf16, 2 B/element) and it cannot be intercepted.  This builder
makes the reduction explicit: the step is ``shard_map``-manual over the
data axes (model axis stays automatic, so TP/EP partitioning inside the
loss is unchanged) and the gradient mean runs through the int8
error-feedback collective of :mod:`repro.parallel.compression` —
1 B/element on the wire, halving the dominant collective term of
gradient-sync-bound train cells (§Perf, qwen2-moe).

State contract: parameters and optimizer state are replicated across the
data axes (no FSDP — the compressed reduction yields bitwise-identical
updates on every shard); the error-feedback residuals are *per-shard*
(leading shard dim, sharded over the data axes).

:class:`FabricGradSync` is the second half of the story: the same
explicit gradient mean, but routed through the simulated FPsPIN fabric's
nonblocking MPI layer (``repro.mpi``) instead of XLA's collective — post
the reduction, keep ticking the fabric from inside the backprop window
(the progress hook), and the multi-MiB gradient vector rides the
segmented Rabenseifner fast path with NIC-side unpack.  That is what the
``grad_allreduce`` benchmark measures: overlap ratio and goodput of a
gradient-sized reduction hidden behind compute.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.parallel import compression as comp
from repro.parallel import sharding as shlib
from repro.train import optimizer as opt


class FabricGradSync:
    """Data-parallel gradient mean over the simulated FPsPIN fabric.

    One instance serves a whole job: every shard's gradient pytree is
    flattened into one contiguous f32 vector (layout captured once, on
    the first post), the vectors allreduce through ``repro.mpi`` — at
    gradient sizes the auto-selector picks segmented Rabenseifner over
    the credit-managed rendezvous path — and the mean is unflattened
    back into per-shard pytrees.

    The point is *overlap*: :meth:`post` returns immediately with the
    collective in flight, :meth:`progress` is the hook the training loop
    calls from inside backprop (each call ticks the fabric forward while
    host compute runs), and :meth:`wait` drains the tail.  ``last_stats``
    reports how much of the transfer the compute window hid.
    """

    def __init__(self, comm, algorithm: str = "auto"):
        self.comm = comm
        self.algorithm = algorithm
        self.handle = None
        self._treedef = None
        self._shapes = None
        self._posted_at = 0
        self._compute_ticks = 0
        self.last_stats: dict = {}

    def _flatten(self, grads) -> np.ndarray:
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        if self._treedef is None:
            self._treedef = treedef
            self._shapes = [(tuple(l.shape), np.dtype(jnp.result_type(l)))
                            for l in leaves]
        assert treedef == self._treedef, "gradient pytree changed shape"
        return np.concatenate(
            [np.asarray(l, np.float32).reshape(-1) for l in leaves]) \
            if leaves else np.zeros(0, np.float32)

    def _unflatten(self, vec: np.ndarray):
        leaves, off = [], 0
        for shape, dtype in self._shapes:
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            leaves.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def post(self, shard_grads) -> None:
        """Post the nonblocking mean of one gradient pytree per shard."""
        from repro import mpi
        assert self.handle is None or self.handle.done, \
            "previous gradient sync still in flight"
        vecs = [self._flatten(g) for g in shard_grads]
        self.grad_bytes = int(vecs[0].nbytes)
        self.handle = mpi.iallreduce(self.comm, vecs,
                                     algorithm=self.algorithm)
        self._posted_at = self.comm.now
        self._compute_ticks = 0

    def progress(self, ticks: int = 1) -> bool:
        """The backprop progress hook: advance the fabric ``ticks`` while
        the caller's compute runs.  Returns True once the sync is done."""
        self._compute_ticks += ticks
        self.comm.progress(ticks)
        return self.handle.test()

    def wait(self, max_ticks: int = 2_000_000):
        """Drain the reduction; returns the per-shard *mean* pytrees and
        records overlap instrumentation in ``last_stats``."""
        t0 = self.comm.now
        self.comm.wait(self.handle, max_ticks=max_ticks)
        t_poll = self.comm.now - t0
        n = self.comm.n_ranks
        total = self.comm.now - self._posted_at
        self.last_stats = dict(
            algorithm=self.handle.algorithm,
            rounds=self.handle.rounds,
            msgs_total=self.handle.msgs_total,
            bytes_wire=self.handle.bytes_wire,
            grad_bytes=self.grad_bytes,
            compute_ticks=self._compute_ticks,
            poll_ticks=t_poll,
            total_ticks=total,
            overlap_ratio=(self._compute_ticks
                           / max(1, self._compute_ticks + t_poll)),
        )
        return [self._unflatten(v / n) for v in self.handle.result]


def error_state_init(params_shapes, n_shards: int):
    """Per-shard EF residuals: (n_shards, *param.shape) f32 (abstract)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((n_shards,) + tuple(p.shape),
                                       jnp.float32), params_shapes)


def build(model: Model, mesh: Mesh, ocfg: opt.OptConfig,
          batch_example) -> Tuple[Any, Any]:
    """Returns (jitted step, in_shardings tuple).

    step(params, opt_state, err, batch) -> (params, opt_state, err, loss)
    """
    cfg = model.cfg
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_shards = int(np.prod([mesh.shape[a] for a in data_axes]))

    def local_step(params, opt_state, err, batch):
        # leaves arrive with their *local* shapes: batch B/n, err (1, ...)
        err = jax.tree.map(lambda e: e[0], err)

        def loss_fn(p):
            loss, m = model.loss_fn(p, batch)
            return loss, m

        (loss, _metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, err = comp.compressed_pmean(grads, err, data_axes)
        loss = jax.lax.pmean(loss, data_axes)
        p2, o2, om = opt.apply_updates(params, opt_state, grads, ocfg)
        err = jax.tree.map(lambda e: e[None], err)
        return p2, o2, err, loss

    # manual over data axes; model axis stays automatic (TP/EP inside)
    rep = P()
    params_specs = jax.tree.map(lambda _: rep, model.init_eval())
    opt_specs = opt.OptState(mu=params_specs, nu=params_specs, step=rep)
    err_specs = jax.tree.map(
        lambda _: P(data_axes if len(data_axes) > 1 else data_axes[0]),
        model.init_eval())
    dspec = data_axes if len(data_axes) > 1 else data_axes[0]

    def batch_spec(path, leaf):
        if len(leaf.shape) == 3 and "positions" in str(path):
            return P(None, dspec, None)
        return P(dspec, *([None] * (len(leaf.shape) - 1)))

    batch_specs = jax.tree_util.tree_map_with_path(batch_spec,
                                                   batch_example)
    # partial-manual shard_map: manual over the data axes only, the model
    # axis stays automatic (TP/EP partitioning inside the loss unchanged)
    sm = jax.shard_map(local_step, mesh=mesh,
                       in_specs=(params_specs, opt_specs, err_specs,
                                 batch_specs),
                       out_specs=(params_specs, opt_specs, err_specs,
                                  rep),
                       axis_names=set(data_axes),
                       check_vma=False)

    # outer pjit supplies the model-axis placement of params/opt
    pshard = shlib.param_shardings(model.init_eval(), cfg, mesh,
                                   fsdp=False)
    oshard = opt.OptState(mu=pshard, nu=pshard,
                          step=shlib.replicated(mesh))
    eshard = jax.tree.map(
        lambda s: NamedSharding(mesh, P(dspec, *s.spec)), pshard)
    bshard = shlib.batch_shardings(batch_example, mesh)
    fn = jax.jit(sm, in_shardings=(pshard, oshard, eshard, bshard),
                 out_shardings=(pshard, oshard, eshard, None),
                 donate_argnums=(0, 1, 2))
    return fn, (pshard, oshard, eshard, bshard)
