"""AdamW with cosine/linear schedules, global-norm clipping and
ZeRO-sharded moments (moments inherit the parameter sharding + FSDP, so
under pjit the optimizer state is partitioned exactly like ZeRO-1/3
depending on the FSDP flag).

Pure functions over pytrees — no optax dependency (offline container).
Moments are f32 regardless of param dtype (bf16-safe update rule).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def init(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params),
                    step=jnp.zeros((), jnp.int32))


def schedule_lr(cfg: OptConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


def apply_updates(params, opt_state: OptState, grads, cfg: OptConfig):
    """One AdamW step.  grads may be bf16; math in f32; params keep their
    dtype.  Returns (params, opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state.mu, opt_state.nu)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_mu, new_nu, step), \
        {"grad_norm": gnorm, "lr": lr}
