"""Pure-jnp oracle for the matching engine kernel."""
from __future__ import annotations

import jax.numpy as jnp


def match_ref(words, rules, modes):
    """words (N, W) uint32; rules (C, 4, 4) uint32; modes (C,) int32.

    Returns (matched, eom) as (N, C) bool arrays.
    """
    idx = rules[:, :, 0].astype(jnp.int32)       # (C, 4)
    mask = rules[:, :, 1]
    start = rules[:, :, 2]
    end = rules[:, :, 3]
    w = words.shape[1]
    sel = jnp.take(words, jnp.clip(idx, 0, w - 1), axis=1)   # (N, C, 4)
    v = sel & mask[None]
    ok = (v >= start[None]) & (v <= end[None])               # (N, C, 4)
    and_mode = ok[..., 0] & ok[..., 1] & ok[..., 2]
    or_mode = ok[..., 0] | ok[..., 1] | ok[..., 2]
    matched = jnp.where(modes[None, :] == 0, and_mode, or_mode)
    return matched, ok[..., 3]
