"""Jit'd public wrapper for the matching-engine kernel.

Pads the packet dimension to the kernel block, dispatches to the Pallas
kernel (``interpret=True`` on CPU — the kernel body executes in Python for
validation; compiled Mosaic on real TPU) or to the jnp reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matcher import matcher as _k
from repro.kernels.matcher import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def match(words: jax.Array, rules: jax.Array, modes: jax.Array,
          use_kernel: bool = False, block_n: int = _k.DEFAULT_BLOCK_N):
    """Returns (matched, eom): (N, C) bool.

    ``use_kernel=False`` (default on CPU hot paths) uses the jnp oracle —
    identical results; the Pallas path is exercised by tests/benchmarks and
    is the TPU deployment path.
    """
    if not use_kernel:
        return _ref.match_ref(words, rules, modes)
    n = words.shape[0]
    pad = (-n) % block_n
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    matched, eom = _k.match_pallas(words, rules, modes, block_n=block_n,
                                   interpret=_interpret())
    return matched[:n].astype(bool), eom[:n].astype(bool)
