"""Pallas TPU kernel for the FPsPIN U32 matching engine (paper §IV block 1).

The hardware matcher inspects one 32-bit word per rule; a ruleset is
3 match rules + 1 EOM rule with an AND/OR combiner.  On TPU we evaluate
*all contexts × all rules* for a block of packets at once, entirely in the
VPU (bitwise ops + compares, no MXU):

  grid:   (N // BLOCK_N,)
  VMEM:   words  (BLOCK_N, W) uint32   -- the packet word view
          rules  (C, 4, 4)    uint32   -- replicated to every block
          modes  (1, C)       int32
  out:    matched, eom  (BLOCK_N, C) int32

Word selection (``words[:, idx[c, r]]``) is done with a broadcasted-iota
compare-and-sum instead of a dynamic gather: the index is a scalar per
(context, rule), so ``sum(where(iota == idx, words, 0), axis=-1)`` is a
single masked row-reduction — the idiomatic Mosaic-friendly form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _matcher_kernel(words_ref, rules_ref, modes_ref, matched_ref, eom_ref,
                    *, n_ctx: int):
    words = words_ref[...]                        # (BN, W) uint32
    rules = rules_ref[...]                        # (C, 4, 4) uint32
    modes = modes_ref[...]                        # (1, C) int32
    bn, w = words.shape
    w_iota = jax.lax.broadcasted_iota(jnp.uint32, (bn, w), 1)

    match_cols = []
    eom_cols = []
    for c in range(n_ctx):
        oks = []
        for r in range(4):
            idx = rules[c, r, 0]
            mask = rules[c, r, 1]
            start = rules[c, r, 2]
            end = rules[c, r, 3]
            # select word `idx` from each packet (exactly one lane matches)
            sel = jnp.sum(jnp.where(w_iota == idx, words, jnp.uint32(0)),
                          axis=1)
            v = sel & mask
            oks.append((v >= start) & (v <= end))
        and_mode = oks[0] & oks[1] & oks[2]
        or_mode = oks[0] | oks[1] | oks[2]
        is_and = modes[0, c] == 0
        match_cols.append(jnp.where(is_and, and_mode, or_mode))
        eom_cols.append(oks[3])
    matched_ref[...] = jnp.stack(match_cols, axis=1).astype(jnp.int32)
    eom_ref[...] = jnp.stack(eom_cols, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def match_pallas(words: jax.Array, rules: jax.Array, modes: jax.Array,
                 block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """words (N, W) uint32, rules (C,4,4) uint32, modes (C,) int32.

    Returns (matched, eom): (N, C) int32 each. N must be a multiple of
    block_n (ops.py pads).
    """
    n, w = words.shape
    c = rules.shape[0]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    out_shape = [jax.ShapeDtypeStruct((n, c), jnp.int32)] * 2
    kernel = functools.partial(_matcher_kernel, n_ctx=c)
    matched, eom = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((c, 4, 4), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, c), lambda i: (i, 0)),
            pl.BlockSpec((block_n, c), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(words, rules, modes.reshape(1, -1))
    return matched, eom
