"""Pure-jnp oracle for the internet-checksum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def checksum_ref(data, lengths, start: int):
    """data (N, MTU) uint8 (zero beyond lengths), lengths (N,) int32.

    Ones-complement 16-bit checksum over bytes [start, lengths) per packet.
    """
    n, mtu = data.shape
    b = data.astype(jnp.uint32).reshape(n, mtu // 2, 2)
    words = (b[:, :, 0] << 8) | b[:, :, 1]
    w_iota = jax.lax.broadcasted_iota(jnp.int32, (n, mtu // 2), 1)
    live = (w_iota >= start // 2) & (w_iota < (lengths[:, None] + 1) // 2)
    s = jnp.sum(jnp.where(live, words, 0), axis=1)
    s = (s & 0xFFFF) + (s >> 16)
    s = (s & 0xFFFF) + (s >> 16)
    return ((~s) & 0xFFFF).astype(jnp.uint32)
