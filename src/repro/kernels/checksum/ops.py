"""Jit'd public wrapper for the checksum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.checksum import checksum as _k
from repro.kernels.checksum import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def internet_checksum(data: jax.Array, lengths: jax.Array, *, start: int,
                      use_kernel: bool = False,
                      block_n: int = _k.DEFAULT_BLOCK_N) -> jax.Array:
    """Batched RFC1071 checksum over bytes [start, length) per packet."""
    if not use_kernel:
        return _ref.checksum_ref(data, lengths, start)
    n = data.shape[0]
    pad = (-n) % block_n
    if pad:
        data = jnp.pad(data, ((0, pad), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad), constant_values=start)
    out = _k.checksum_pallas(data, lengths, start=start, block_n=block_n,
                             interpret=_interpret())
    return out[:n]
