"""Pallas TPU kernel: batched RFC1071 internet checksum (ICMP responder).

The paper's ICMP ping-pong handler spends its time in a portable-C
ones-complement checksum loop — the dominant cost of Fig 7's linear RTT
growth.  The batched TPU form: one grid step checksums BLOCK_N packets at
once; bytes are widened to u16 big-endian words, lanes beyond each packet's
length are masked, and the 32-bit partial sum is end-around-carry folded.

  grid:  (N // BLOCK_N,)
  VMEM:  data (BLOCK_N, MTU) uint8 -> internally (BLOCK_N, MTU/2) words
         meta (BLOCK_N, 1)  int32  -- payload byte length (from `start`)
  out:   (BLOCK_N, 1) uint32       -- folded ~sum & 0xffff

``start`` (the L4 offset, 34 for ICMP) is static.  Bytes past ``length``
must be zero in the buffer (PacketBatch guarantees this); the word mask
only needs whole-word granularity because a trailing odd byte pairs with a
guaranteed-zero pad byte — the same trick the C handler uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _checksum_kernel(data_ref, len_ref, out_ref, *, start: int):
    data = data_ref[...]                        # (BN, MTU) uint8
    nbytes = len_ref[...]                       # (BN, 1) int32
    bn, mtu = data.shape
    b = data.astype(jnp.uint32).reshape(bn, mtu // 2, 2)
    words = (b[:, :, 0] << 8) | b[:, :, 1]      # (BN, MTU/2) u16be in u32
    w_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, mtu // 2), 1)
    first = start // 2                          # start is even (34)
    last = (nbytes + 1) // 2                    # exclusive word index
    live = (w_iota >= first) & (w_iota < last)
    s = jnp.sum(jnp.where(live, words, jnp.uint32(0)), axis=1)
    # end-around carry: sum of <=768 0xffff words fits u32; two folds suffice
    s = (s & 0xFFFF) + (s >> 16)
    s = (s & 0xFFFF) + (s >> 16)
    out_ref[...] = ((~s) & 0xFFFF).reshape(bn, 1).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("start", "block_n", "interpret"))
def checksum_pallas(data: jax.Array, lengths: jax.Array, *, start: int,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: bool = True) -> jax.Array:
    """data (N, MTU) uint8, lengths (N,) int32 -> (N,) uint32 checksums."""
    n, mtu = data.shape
    assert n % block_n == 0 and mtu % 2 == 0
    grid = (n // block_n,)
    kernel = functools.partial(_checksum_kernel, start=start)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, mtu), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.uint32),
        interpret=interpret,
    )(data, lengths.reshape(n, 1).astype(jnp.int32))
    return out.reshape(n)
