"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (H, Sq, D); k, v (H, Sk, D).  Plain materialized softmax."""
    h, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("hqd,htd->hqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask[None], p, 0.0)
    o = jnp.einsum("hqt,htd->hqd", p, v.astype(jnp.float32))
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return (o / denom).astype(q.dtype)
