"""Pallas TPU flash-attention kernel (forward): the §Perf answer to the
HLO attention floor.

The dry-run showed (EXPERIMENTS §Perf, iterations H4/H5) that ~80 % of a
train cell's memory term is S²-shaped score/probability traffic that HLO
*must* materialize between the QKᵀ and PV dots.  A fused kernel keeps
those blocks in VMEM: HBM sees only Q, K, V, O — the flash-attention
trade.  This kernel implements the online-softmax streaming form with
explicit BlockSpec tiling:

  grid:  (B·KV·G heads, Sq/BQ, Sk/BK)   — causal/window blocks that are
                                           fully masked are skipped via
                                           pl.when on the block indices
  VMEM:  q (BQ, D), k/v (BK, D), f32 scratch: acc (BQ, D), m/l (BQ,)
  HBM:   q, k, v in; o out — no S² tensor ever leaves VMEM

Numerics match models/attention.blockwise_attention (same online-softmax
recurrence, f32 stats): validated in interpret mode against it in
tests/test_kernels.py.  Backward runs through recompute
(jax.checkpoint around the op); the fwd kernel is where the S² traffic
lived.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:                                     # TPU scratch memory spaces
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                        # pragma: no cover - CPU fallback
    _VMEM = None

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, block_q: int, block_k: int,
                  nk: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    # block-level static-shape mask test (traced on block indices)
    run = jnp.bool_(True)
    if causal:
        run = run & (ki * block_k <= qi * block_q + block_q - 1)
    if window > 0:
        run = run & (ki * block_k + block_k - 1 > qi * block_q - window)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32) * scale   # (BQ, D)
        k = k_ref[...].astype(jnp.float32)           # (BK, D)
        v = v_ref[...]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (BQ, BK)
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int = 0,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q (H, Sq, D); k, v (H, Sk, D) — heads flattened (B·KV·G for GQA,
    with k/v pre-broadcast per group).  Returns (H, Sq, D).
    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads)."""
    h, sq, d = q.shape
    _, sk, _ = k.shape
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    scale = float(1.0 / np.sqrt(d))
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, block_q=bq,
        block_k=bk, nk=nk, scale=scale)
    if _VMEM is not None:
        scratch = [_VMEM((bq,), jnp.float32), _VMEM((bq,), jnp.float32),
                   _VMEM((bq, d), jnp.float32)]
    else:                                # pragma: no cover
        scratch = [jax.ShapeDtypeStruct((bq,), jnp.float32)] * 2 + \
            [jax.ShapeDtypeStruct((bq, d), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(h, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda hh, qi, ki: (hh, qi, 0)),
            pl.BlockSpec((None, bk, d), lambda hh, qi, ki: (hh, ki, 0)),
            pl.BlockSpec((None, bk, d), lambda hh, qi, ki: (hh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d),
                               lambda hh, qi, ki: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
