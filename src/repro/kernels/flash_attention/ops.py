"""Jit'd wrapper: GQA-shaped entry point for the flash-attention kernel.

Takes the model's (B, S, H, D) / (B, S, KV, D) layout, folds batch and
head dims into the kernel's flat head axis (broadcasting K/V across the
GQA group), pads sequence to kernel blocks, and dispatches Pallas
(interpret on CPU) or the jnp oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _k
from repro.kernels.flash_attention import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_kernel: bool = False,
                    block_q: int = _k.DEFAULT_BLOCK_Q,
                    block_k: int = _k.DEFAULT_BLOCK_K):
    """q (B, Sq, H, D); k, v (B, Sk, KV, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * h, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * h, sk, d)
    if not use_kernel:
        of = _ref.flash_attention_ref(qf, kf, vf, causal=causal,
                                      window=window)
    else:
        bq = min(block_q, sq)
        bk = min(block_k, sk)
        pad_q = (-sq) % bq
        pad_k = (-sk) % bk
        if pad_q:
            qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
        if pad_k:
            kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
        of = _k.flash_attention_pallas(qf, kf, vf, causal=causal,
                                       window=window, block_q=bq,
                                       block_k=bk,
                                       interpret=_interpret())
        of = of[:, :sq]
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
