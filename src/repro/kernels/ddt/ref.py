"""Pure-jnp oracle for ddt_gather."""
from __future__ import annotations

import jax.numpy as jnp


def ddt_gather_ref(src, idx, fill=0):
    """out[i] = src[idx[i]] if idx[i] >= 0 else fill."""
    s = src.shape[0]
    safe = jnp.clip(idx, 0, s - 1)
    vals = jnp.take(src, safe, axis=0)
    return jnp.where(idx >= 0, vals, jnp.asarray(fill, src.dtype))
