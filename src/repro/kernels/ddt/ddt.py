"""Pallas TPU kernel for MPI derived-datatype (un)pack — ``ddt_gather``.

FPsPIN's hottest loop is the MPICH *dataloop* engine running on 40 MHz HPU
cores, walking nested vector/hvector descriptors byte by byte (paper §V-C).
The TPU-native adaptation (DESIGN.md §2) compiles the datatype **once** into
an element index map (runtime code specialization, the technique the paper
cites as [44]) and turns both pack and unpack into a single primitive:

    out[i] = idx[i] >= 0 ? src[idx[i]] : fill

executed as a tiled, accumulate-over-source-blocks kernel:

  grid:  (I // BI, S // BS)          I = index count, S = source elements
  VMEM:  idx  (1, BI) int32          out tile's source indices
         src  (1, BS) dtype          one source block
  out:   (1, BI) dtype, revisited across the S dimension (accumulation)

Each source block contributes ``where(idx - base == iota, src, 0)`` summed
over the block — an exact masked-select gather that never needs a dynamic
vector gather (works for all dtypes, MXU-free, fully vectorized on the
VPU).  Exactly one source block contributes per element, so ``+=`` across
the grid's S dimension reconstructs the gather exactly (zero is the
additive identity for the masked lanes in every dtype).

VMEM budget per step: BI*4 + BS*esize + BI*BS*esize bytes for the broadcast
compare; defaults (BI=512, BS=512, f32) ≈ 1.05 MiB — comfortably inside
16 MiB VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_I = 512
DEFAULT_BLOCK_S = 512


def _gather_kernel(idx_ref, src_ref, out_ref, *, block_s: int, fill):
    s_blk = pl.program_id(1)
    idx = idx_ref[...]                               # (1, BI) int32
    src = src_ref[...]                               # (1, BS) dtype
    dtype = src.dtype
    base = (s_blk * block_s).astype(jnp.int32)
    rel = idx - base                                 # (1, BI)
    bi = idx.shape[1]
    # (BI, BS) compare grid: rel[i] == s for the in-block source position
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (bi, block_s), 1)
    hit = rel.reshape(bi, 1) == s_iota               # (BI, BS) bool
    contrib = jnp.where(hit, jnp.broadcast_to(src.reshape(1, block_s),
                                              (bi, block_s)),
                        jnp.zeros((), dtype))
    partial = contrib.sum(axis=1, dtype=jnp.float32) if \
        jnp.issubdtype(dtype, jnp.floating) else contrib.sum(axis=1)
    partial = partial.astype(dtype).reshape(1, bi)

    @pl.when(s_blk == 0)
    def _init():
        # negative index -> fill value (holes in the datatype)
        out_ref[...] = jnp.where(idx < 0, jnp.asarray(fill, dtype),
                                 jnp.zeros((), dtype))

    out_ref[...] += partial


@functools.partial(jax.jit,
                   static_argnames=("block_i", "block_s", "interpret",
                                    "fill"))
def ddt_gather_pallas(src: jax.Array, idx: jax.Array, *, fill=0,
                      block_i: int = DEFAULT_BLOCK_I,
                      block_s: int = DEFAULT_BLOCK_S,
                      interpret: bool = True) -> jax.Array:
    """src (S,) dtype; idx (I,) int32 with -1 = hole.  Returns (I,) dtype.

    S % block_s == 0 and I % block_i == 0 (ops.py pads).
    """
    (s,) = src.shape
    (i,) = idx.shape
    assert s % block_s == 0 and i % block_i == 0, (s, i, block_s, block_i)
    grid = (i // block_i, s // block_s)
    kernel = functools.partial(_gather_kernel, block_s=block_s, fill=fill)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_i), lambda ib, sb: (0, ib)),
            pl.BlockSpec((1, block_s), lambda ib, sb: (0, sb)),
        ],
        out_specs=pl.BlockSpec((1, block_i), lambda ib, sb: (0, ib)),
        out_shape=jax.ShapeDtypeStruct((1, i), src.dtype),
        interpret=interpret,
    )(idx.reshape(1, i), src.reshape(1, s))
    return out.reshape(i)
