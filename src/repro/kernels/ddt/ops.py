"""Jit'd public wrappers: pack / unpack / gather for DDT processing.

``pack``   : serialize a non-contiguous source buffer into a message
             (out[i] = buf[pack_idx[i]]).
``unpack`` : scatter a packed message into a destination buffer
             (dst[j]  = msg[unpack_idx[j]] where unpack_idx[j] >= 0,
              else keep dst[j]).

Both are expressed through one gather primitive; the index maps come from
:mod:`repro.core.ddt` (the dataloop "commit" step).  Padding to kernel
blocks happens here so callers never see alignment constraints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ddt import ddt as _k
from repro.kernels.ddt import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gather(src: jax.Array, idx: jax.Array, *, fill=0,
           use_kernel: bool = False,
           block_i: int = _k.DEFAULT_BLOCK_I,
           block_s: int = _k.DEFAULT_BLOCK_S) -> jax.Array:
    """out[i] = src[idx[i]] (idx -1 -> fill). 1-D src/idx, any dtype."""
    if not use_kernel:
        return _ref.ddt_gather_ref(src, idx, fill)
    s, i = src.shape[0], idx.shape[0]
    pad_s = (-s) % block_s
    pad_i = (-i) % block_i
    if pad_s:
        src = jnp.pad(src, (0, pad_s))
    if pad_i:
        idx = jnp.pad(idx, (0, pad_i), constant_values=-1)
    out = _k.ddt_gather_pallas(src, idx, fill=fill, block_i=block_i,
                               block_s=block_s, interpret=_interpret())
    return out[:i]


def pack(buf: jax.Array, pack_idx: jax.Array, use_kernel: bool = False
         ) -> jax.Array:
    """Serialize: message[i] = buf[pack_idx[i]]."""
    return gather(buf, pack_idx, fill=0, use_kernel=use_kernel)


def unpack(msg: jax.Array, unpack_idx: jax.Array, dst: jax.Array,
           use_kernel: bool = False) -> jax.Array:
    """De-serialize into dst: positions with unpack_idx >= 0 receive
    msg[unpack_idx]; others keep their existing value (datatype holes)."""
    gathered = gather(msg, unpack_idx, fill=0, use_kernel=use_kernel)
    return jnp.where(unpack_idx >= 0, gathered, dst)
