"""Sharding rules: parameter / optimizer / activation / cache partitioning.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  ``pod`` and ``data`` are both data-parallel (batch shards over
their product); ``model`` carries tensor/expert parallelism.

Policy (MaxText-style, divisibility-gated):
  * embeddings / lm_head        : vocab over ``model`` when divisible
  * attention q/o               : head dim (as q_dim columns) over ``model``
                                  when n_heads divides the axis; replicated
                                  otherwise (documented per arch)
  * attention k/v               : over ``model`` when n_kv_heads divides
  * MLP up/gate/down            : d_ff over ``model`` (always divisible for
                                  the assigned archs)
  * MoE experts                 : expert dim over ``model`` (EP)
  * mamba2 / rg-lru mixers      : lru/inner width over ``model`` where
                                  divisible, else replicated
  * FSDP (flag)                 : additionally shard the d_model dim of
                                  matrices over ``data`` (ZeRO-3); XLA
                                  inserts the all-gathers
  * optimizer moments           : same spec as their parameter (+ FSDP)
  * activations                 : batch over (pod, data)
  * KV caches                   : batch over (pod, data) when divisible;
                                  long-context (batch 1): cache sequence
                                  over ``data`` (sequence parallelism)

Stacked period-scan params carry a leading ``periods`` dim -> specs are
right-aligned against the trailing dims.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh, fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf, by path suffix + shape."""
    tp = _axis_size(mesh, "model")
    dp = _axis_size(mesh, "data")

    def fs(dim: int) -> Optional[str]:
        """FSDP-shard helper for a d_model-sized dim."""
        return "data" if (fsdp and _div(dim, dp)) else None

    leaf = path.split("/")[-1]
    base: Tuple[Optional[str], ...]

    # ---- embeddings
    if leaf == "tok":
        v, d = shape[-2:]
        base = ("model" if _div(v, tp) else None, fs(d))
    elif leaf == "lm_head":
        d, v = shape[-2:]
        base = (fs(d), "model" if _div(v, tp) else None)
    # ---- attention
    elif leaf in ("wq", "wo", "bq") or "attn/" in path and leaf in ("wq",):
        heads_ok = _div(cfg.n_heads, tp)
        if leaf == "wq":
            base = (fs(shape[-2]), "model" if heads_ok else None)
        elif leaf == "wo":
            base = ("model" if heads_ok else None, fs(shape[-1]))
        else:                                     # bq
            base = ("model" if heads_ok else None,)
    elif leaf in ("wk", "wv", "bk", "bv"):
        kv_ok = _div(cfg.n_kv_heads, tp)
        if leaf in ("wk", "wv"):
            base = (fs(shape[-2]), "model" if kv_ok else None)
        else:
            base = ("model" if kv_ok else None,)
    elif leaf in ("q_norm", "k_norm"):
        base = (None,)
    # ---- MoE (shared-expert rules must precede the generic expert rule:
    #      their path also contains "moe/")
    elif "shared/" in path and leaf in ("up", "gate"):
        base = (fs(shape[-2]), "model" if _div(shape[-1], tp) else None)
    elif "shared/" in path and leaf == "down":
        base = ("model" if _div(shape[-2], tp) else None, fs(shape[-1]))
    elif "moe/" in path and leaf in ("up", "gate"):
        base = ("model", fs(shape[-2]), None)     # EP over experts
    elif "moe/" in path and leaf == "down":
        base = ("model", None, fs(shape[-1]))
    elif leaf == "router":
        base = (None, None)
    # ---- dense MLP
    elif "mlp/" in path and leaf in ("up", "gate"):
        base = (fs(shape[-2]), "model" if _div(shape[-1], tp) else None)
    elif "mlp/" in path and leaf == "down":
        base = ("model" if _div(shape[-2], tp) else None, fs(shape[-1]))
    # ---- mamba2
    elif leaf == "in_proj":
        base = (fs(shape[-2]), None)              # mixed segments: replicate
    elif leaf == "out_proj":
        base = ("model" if _div(shape[-2], tp) else None, fs(shape[-1]))
    elif leaf in ("conv_w", "conv_b", "a_log", "dt_bias", "d_skip", "norm"):
        base = tuple(None for _ in range(min(len(shape), 2)))
    # ---- rg-lru
    elif leaf in ("w_x", "w_gate"):
        base = (fs(shape[-2]), "model" if _div(cfg.lru_width, tp) else None)
    elif leaf in ("w_r", "w_i"):
        base = (None, "model" if _div(cfg.lru_width, tp) else None)
    elif leaf in ("b_r", "b_i", "lam"):
        base = ("model" if _div(cfg.lru_width, tp) else None,)
    elif leaf == "out":
        base = ("model" if _div(cfg.lru_width, tp) else None, fs(shape[-1]))
    # ---- norms & scalars
    elif leaf == "scale" or len(shape) <= 1:
        base = (None,) * min(len(shape), 1)
    else:
        base = (None,) * len(shape)

    # right-align against the leaf's rank (period-scan stacking dim etc.)
    pad = len(shape) - len(base)
    assert pad >= 0, (path, shape, base)
    return P(*((None,) * pad + tuple(base)))


def param_shardings(params_tree, cfg: ModelConfig, mesh: Mesh,
                    fsdp: bool = False):
    """Pytree of NamedShardings matching ``params_tree`` (shapes or
    arrays)."""

    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, cfg, mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def param_shardings_puredp(params_tree, cfg: ModelConfig, mesh: Mesh):
    """Pure data-parallel + ZeRO-3 layout (§Perf beyond-paper sharding):
    no tensor parallelism — the batch shards over *both* mesh axes and
    every parameter is fully sharded (FSDP) across whichever axes its dims
    divide.  Eliminates per-layer activation all-reduces in exchange for
    per-layer parameter all-gathers (cheap when params ≪ activations).
    Greedy: largest dim takes 'data', another divisible dim takes
    'model'; falls back to single-axis or replication."""
    dp = _axis_size(mesh, "data")
    tp = _axis_size(mesh, "model")

    def one(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 1 and shape:
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            # skip the period-scan stacking dim (dim 0 of rank>=3 stacks)
            used_axes = []
            for dim in order:
                if len(spec) >= 3 and dim == 0:
                    continue
                if "data" not in used_axes and _div(shape[dim], dp):
                    spec[dim] = "data"
                    used_axes.append("data")
                elif "model" not in used_axes and _div(shape[dim], tp) \
                        and spec[dim] is None:
                    spec[dim] = "model"
                    used_axes.append("model")
                if len(used_axes) == 2:
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params_tree)


def batch_shardings_puredp(batch_tree, mesh: Mesh):
    """Batch over (pod, data, model) — every chip takes samples."""
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    n = int(np.prod([mesh.shape[a] for a in axes]))

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        bdim = 1 if (name.endswith("positions") and len(shape) == 3) else 0
        spec = [None] * len(shape)
        if _div(shape[bdim], n):
            spec[bdim] = axes
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


# -------------------------------------------------------------- activations
def data_batch_spec(mesh: Mesh, batch: int, rank: int,
                    batch_dim: int = 0) -> P:
    """Batch-sharded activation spec; falls back to replication when the
    batch doesn't divide the data axes (long-context batch=1)."""
    axes = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    spec = [None] * rank
    if _div(batch, n):
        spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def batch_shardings(batch_tree, mesh: Mesh):
    """Input-batch shardings: leading dim over (pod, data); M-RoPE
    positions (3, B, S) shard dim 1."""

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name.endswith("positions") and len(shape) == 3:
            return NamedSharding(mesh,
                                 data_batch_spec(mesh, shape[1], 3, 1))
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh,
                             data_batch_spec(mesh, shape[0], len(shape)))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


# -------------------------------------------------------------- KV caches
def cache_shardings(cache_tree, cfg: ModelConfig, mesh: Mesh,
                    long_context: bool = False):
    """Decode-cache shardings.

    Normal decode: batch over (pod, data), kv-heads over model when
    divisible.  Long-context (batch=1): the cache *sequence* dim shards
    over ``data`` (sequence parallelism) for full-attention layers."""
    tp = _axis_size(mesh, "model")
    dp = _axis_size(mesh, "data")
    kv_ok = _div(cfg.n_kv_heads, tp)

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        leafname = name.split("/")[-1]
        if leafname in ("k", "v", "xk", "xv"):
            b, c = shape[-4], shape[-3]
            spec = [None] * len(shape)
            bspec = data_batch_spec(mesh, b, 1, 0)[0]
            spec[-4] = bspec
            if long_context and bspec is None and _div(c, dp):
                spec[-3] = "data"
            if kv_ok:
                spec[-2] = "model"
            elif _div(c, tp) and spec[-3] is None:
                # kv heads don't divide the model axis: shard the cache
                # *sequence* dim instead (attention contracts over it, so
                # XLA reduces partial scores with a psum) — without this,
                # 32k-cache decode at batch 128 exceeds HBM for kv<16
                # archs (see EXPERIMENTS §Dry-run).
                spec[-3] = "model"
            return NamedSharding(mesh, P(*spec))
        if leafname in ("conv", "h", "ssd"):
            offsets = {"conv": 3, "h": 2, "ssd": 4}
            bdim = len(shape) - offsets[leafname]
            spec = [None] * len(shape)
            spec[bdim] = data_batch_spec(mesh, shape[bdim], 1, 0)[0]
            if leafname == "h" and _div(shape[-1], tp):
                spec[-1] = "model"               # recurrent width
            if leafname == "ssd" and _div(shape[-3], tp):
                spec[-3] = "model"               # SSD heads
            return NamedSharding(mesh, P(*spec))
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
