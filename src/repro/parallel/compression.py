"""Gradient compression: int8 quantized data-parallel all-reduce with
error feedback.

At 1000+ node scale the data-parallel gradient all-reduce dominates the
step's collective bytes (see EXPERIMENTS §Roofline: train cells are
collective-bound for the small-d_model archs).  This module provides a
drop-in compressed psum over the ``data`` axis:

  q   = round(g / s) clipped to int8, s = max|g| / 127  (per-tensor scale)
  e' += g - q*s                (error feedback, carried in CompressionState)
  G   = psum(q) * mean(s)      (int8 payload on the wire, f32 accumulate)

8 bits instead of 32/16 cuts all-reduce bytes 2–4×.  Error feedback makes
the scheme unbiased over time (residuals re-enter the next step), the
standard convergence guarantee for EF-SGD-style methods.

Implementation notes: inside an automatically-partitioned (pjit) program
one cannot intercept XLA's gradient psum, so the trainer uses this through
``shard_map`` over the data axes — the gradients enter as per-device
partials and the collective is explicit (``manual_dp`` mode in
train/trainer.py).  Tested standalone against an uncompressed psum in
tests/test_compression.py.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class CompressionState(NamedTuple):
    error: Any            # pytree like grads, f32 residuals


def init_state(grads_shape_tree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                           grads_shape_tree))


def compress_psum_leaf(g: jax.Array, err: jax.Array, axis_names
                       ) -> Tuple[jax.Array, jax.Array]:
    """One leaf inside shard_map: returns (mean-reduced g, new error).

    Wire-true int8: the scale is shared across shards (one scalar pmax)
    and chosen as max|g| / (127/n), so the *sum* of n int8 payloads never
    exceeds ±127 — the all-reduce really moves 1 byte/element (vs 2 for
    the bf16 baseline), with no wraparound.  The aggressive quantum
    (⌊127/n⌋ levels per shard) is repaid by error feedback across steps.
    """
    g32 = g.astype(jnp.float32) + err
    n = 1
    for a in axis_names:
        n = n * jax.lax.psum(1, a)
    gmax = jnp.max(jnp.abs(g32))
    if axis_names:
        gmax = jax.lax.pmax(gmax, axis_names)
    scale = jnp.maximum(gmax, 1e-12) / (127.0 / n)
    lim = jnp.floor(127.0 / n)
    q = jnp.clip(jnp.round(g32 / scale), -lim, lim).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q, axis_names)          # int8 on the wire
    return total.astype(jnp.float32) * scale / n, new_err


def compressed_pmean(grads, error_tree, axis_names):
    """Compressed mean-all-reduce of a gradient pytree (inside shard_map).
    Returns (reduced grads, new error tree) — both plain pytrees so the
    shard_map out_specs mirror the in_specs structure."""
    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_tree)
    out, errs = [], []
    for g, e in zip(flat_g, flat_e):
        r, ne = compress_psum_leaf(g, e, axis_names)
        out.append(r)
        errs.append(ne)
    return (jax.tree.unflatten(tree, out),
            jax.tree.unflatten(tree, errs))


def make_compressed_allreduce(mesh: Mesh, grads_specs):
    """shard_map-wrapped compressed gradient mean over the data axes.

    grads enter sharded over (pod, data) on their batch-partial dimension
    is not required — each device holds its *local* gradient (replicated
    spec within the model axis); the wrapper performs the cross-data
    reduction with int8 payload."""
    from jax.experimental.shard_map import shard_map

    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def fn(grads, err):
        return compressed_pmean(grads, err, axes)

    # gradients per-device partial: replicated spec (manual mode sees
    # local shards); model-axis sharding stays untouched.
    return shard_map(
        fn, mesh=mesh,
        in_specs=(grads_specs, grads_specs),
        out_specs=(grads_specs, grads_specs),
        check_rep=False)
