"""The per-rank MPI host engine — MPI's progress engine as a fabric node.

One :class:`MpiHostEngine` rides on each rank's :class:`~repro.net.node.Node`
and implements the host half of the messaging layer:

  * **tag matching** with MPI semantics: posted receives match in post
    order, arrivals match in arrival order, ``ANY_SOURCE`` / ``ANY_TAG``
    wildcards, and an unexpected-message queue for sends that beat their
    receive;
  * **eager protocol** (small messages): payload goes straight out over
    the SLMP sender state machine to the peer's NIC eager context, which
    reassembles it into a per-sender staging slot; a FIN control message
    (sent once every segment is ACKed, so the data is known to be in host
    memory) carries the envelope and triggers matching;
  * **rendezvous protocol** (registered datatypes at/above the eager
    threshold): RTS → match → CTS (carrying a receive slot *and a credit
    count*) → SLMP data to the NIC *DDT-unpack* context — the receive-side
    datatype processing runs entirely on the NIC, scattering payload bytes
    through the committed index map into the posted region — → FIN
    completes the receive with a masked copy-out (no host unpack on the
    critical path).

**Credit-managed rendezvous.** Receive slots are *credits*: the receiver
owns ``n_rdv_slots`` leases, debits one per CTS, and returns it the
moment the FIN lands — no time-based quarantine.  Safe reuse is
end-to-end, not clock-based: each grant hands out a *generation-tagged*
virtual slot and arms the NIC's expected-msg_id table
(:meth:`~repro.net.node.Node.write_expect`) before the CTS leaves, so a
stale retransmit of a previous occupant — even one that sat queued in a
congested link arbitrarily long — is dropped on the device instead of
scribbling the recycled region.  Every CTS carries the receiver's
remaining credit, and the sender pipelines its queued rendezvous sends
per destination against that window (at least one RTS is always
outstanding as a probe, so a collapsed window reopens as soon as a grant
arrives).  K concurrent segmented collectives therefore share the slot
pool by grant order without deadlock and without flooding the control
wire with RTSs that cannot be granted: ``credit_stalls`` (receiver had a
matched RTS but no lease) and ``window_stalls`` (sender held an RTS
back) in :attr:`stats` show where the pipeline throttles.

All control traffic uses the reliable :class:`~repro.mpi.wire.CtlEndpoint`;
all bulk data uses SLMP retransmission — the whole layer survives loss,
duplication and reordering.

**Checkpointing.** Every continuation in the engine is a plain-data
record, never a closure: send-side transfers carry their protocol fields
in the in-flight entry and are finished by :meth:`_sender_done`; control
acks dispatch serializable tokens through :meth:`_on_tok_acked`; live
:class:`Request` handles are tracked by integer id in a registry.  That
makes :meth:`snapshot` / :meth:`restore` total — an engine checkpointed
mid-collective restores into a fresh object graph and continues
bit-identically (the fabric's :meth:`~repro.net.fabric.Fabric.checkpoint`
path calls straight into these).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import packet as pkt
from repro.core import slmp
from repro.mpi import wire
from repro.mpi.datatypes import DatatypeRegistry
from repro.net.node import HostEngine

ANY_SOURCE = wire.ANY_SOURCE
ANY_TAG = wire.ANY_TAG
MAX_TAG = (1 << 30) - 1


@dataclasses.dataclass(frozen=True)
class MpiParams:
    """Resolved, rank-independent parameters (built by the Communicator)."""
    n_ranks: int
    macs: Tuple[bytes, ...]
    eager_threshold: int
    eager_slots_per_src: int
    eager_slot_bytes: int
    eager_base: int
    n_rdv_slots: int
    rdv_region_bytes: int
    rdv_base: int
    slot_quarantine: int          # ticks before a freed *eager* staging
    #                               slot is reusable (rdv slots recycle
    #                               instantly via the expect table)
    mtu_payload: int
    slmp_window: int
    slmp_timeout: int
    slmp_max_retries: int
    ctl_timeout: int
    ctl_max_retries: int


class Request:
    """Nonblocking operation handle (MPI_Request).

    ``test()`` probes completion without ticking the fabric; ``wait()``
    drives the owning communicator until done.  For receives,
    ``source``/``tag``/``nbytes`` report the matched envelope (MPI_Status)
    after completion.  ``rid`` is the engine-local id live requests are
    checkpointed under; ``ctoken`` names the collective-plan step this
    request belongs to (plain data — restored plans re-attach their
    callbacks by token).
    """

    def __init__(self, kind: str, buf: Optional[np.ndarray] = None,
                 source: int = ANY_SOURCE, tag: int = ANY_TAG):
        self.kind = kind                  # "send" | "recv" | "coll"
        self.buf = buf
        self.buf_id: Optional[int] = None  # BufferPool binding (checkpoint)
        self.source = source              # recv: match filter, then sender
        self.tag = tag
        self.done = False
        self.error: Optional[str] = None
        self.nbytes = 0
        self.rid = -1
        self.ctoken: Optional[tuple] = None  # (plan_id, step_key)
        self._comm = None                 # set by the Communicator
        self._cbs: List[Callable[["Request"], None]] = []

    def test(self) -> bool:
        """MPI_Test: completion probe — never blocks, never ticks."""
        return self.done

    def wait(self, max_ticks: int = 100_000) -> "Request":
        """MPI_Wait: tick the owning communicator until complete."""
        assert self._comm is not None, \
            "request has no communicator: use comm.wait(req)"
        self._comm.wait(self, max_ticks=max_ticks)
        return self

    def add_done_callback(self, cb: Callable[["Request"], None]) -> None:
        if self.done:
            cb(self)
        else:
            self._cbs.append(cb)

    def _complete(self, source: Optional[int] = None,
                  tag: Optional[int] = None, nbytes: int = 0,
                  error: Optional[str] = None) -> None:
        assert not self.done
        if source is not None:
            self.source = source
        if tag is not None:
            self.tag = tag
        self.nbytes = nbytes
        self.error = error
        self.done = True
        cbs, self._cbs = self._cbs, []
        for cb in cbs:
            cb(self)

    def __repr__(self):
        state = "done" if self.done else "pending"
        return (f"Request({self.kind}, {state}, src={self.source}, "
                f"tag={self.tag}, nbytes={self.nbytes})")


@dataclasses.dataclass
class _Envelope:
    """Unexpected-queue entry: an arrived eager message (payload already
    copied out of the staging slot) or a pending rendezvous RTS."""
    kind: str                 # "eager" | "rts"
    ctl: wire.Ctl
    payload: Optional[np.ndarray] = None


def _u8view(buf: np.ndarray) -> np.ndarray:
    assert buf.flags["C_CONTIGUOUS"], "MPI buffers must be C-contiguous"
    return buf.reshape(-1).view(np.uint8)


def _env_snap(e: _Envelope) -> tuple:
    return (e.kind, dataclasses.astuple(e.ctl),
            None if e.payload is None else e.payload.copy())


def _env_restore(t: tuple) -> _Envelope:
    kind, ctl, payload = t
    return _Envelope(kind, wire.Ctl(*ctl),
                     None if payload is None else payload.copy())


class MpiHostEngine(HostEngine):
    def __init__(self, rank: int, registry: DatatypeRegistry,
                 params: MpiParams, pool=None):
        self.rank = rank
        self.registry = registry
        self.p = params
        self.pool = pool                        # BufferPool (checkpointing)
        self._node = None                       # set by attach()
        self.ctl = wire.CtlEndpoint(rank, list(params.macs),
                                    timeout=params.ctl_timeout,
                                    max_retries=params.ctl_max_retries)
        self.ctl.deliver = self._on_ctl
        self.ctl.on_acked = self._on_tok_acked
        self.ctl.on_give_up = self._on_ctl_give_up
        self._now = 0
        # ---- request registry (live, incomplete requests by id)
        self._reqs: Dict[int, Request] = {}
        self._next_rid = 0
        # ---- send side.  Entries are plain-data dicts carrying every
        # field their continuation needs (no closures anywhere).
        self._eager_seq: Dict[int, int] = {}
        self._msg_seq: Dict[int, int] = {}
        self._mseq_tx: Dict[int, int] = {}      # matching seq per dest
        self._eager_queue: Dict[int, Deque[dict]] = {}
        self._eager_inflight: Dict[int, Dict[int, dict]] = {}
        # (dest, slot) -> tick before which the staging slot must not be
        # reused: a duplicated/reorder-delayed data frame of the previous
        # message (same msg_id — the NIC addresses purely by slot) could
        # still be in flight right after its FIN is acked
        self._eager_cooldown: Dict[Tuple[int, int], int] = {}
        self._rdv_sends: Dict[Tuple[int, int], dict] = {}
        # credit-window RTS pipeline: queued rendezvous sends per dest,
        # the per-dest window learned from CTS credits, and the number of
        # transfers between RTS and FIN-ack per dest
        self._rdv_queue: Dict[int, Deque[dict]] = {}
        self._rdv_window: Dict[int, int] = {}
        self._rdv_outstanding: Dict[int, int] = {}
        self._active: List[dict] = []           # live SLMP data senders
        # ---- receive side
        self._posted: List[Request] = []
        self._unexpected: Deque[_Envelope] = deque()
        # MPI non-overtaking: envelopes from one sender enter tag matching
        # in *send* order (mseq), even though an RTS datagram can beat an
        # earlier eager message's FIN onto the wire
        self._mseq_rx: Dict[int, int] = {}
        self._mseq_pending: Dict[int, Dict[int, _Envelope]] = {}
        self._rdv_recv: Dict[int, Tuple[int, wire.Ctl]] = {}   # vslot -> rid
        self._free_slots: List[int] = list(range(params.n_rdv_slots))
        # per-physical-slot generation: the CTS hands out the *virtual*
        # slot gen·n_slots+phys, the NIC is armed with the full expected
        # msg_id, and stale frames of earlier generations are dropped on
        # the device — so a FIN'd slot recycles immediately (no time-based
        # quarantine on the rendezvous path)
        self._slot_gen: List[int] = [0] * params.n_rdv_slots
        self._cts_waiting: Deque[Tuple[int, wire.Ctl]] = deque()  # (rid, rts)
        # ---- accounting
        self.stats = dict(eager_sent=0, rdv_sent=0, bytes_sent=0,
                          bytes_recv=0, unexpected=0, retransmits=0,
                          credit_stalls=0, window_stalls=0)
        self.errors: List[str] = []

    def attach(self, node) -> None:
        """Bind to the Node whose NIC host window we read (the mmap view)."""
        self._node = node

    # ----------------------------------------------------- request registry
    def _new_request(self, kind: str, **kw) -> Request:
        req = Request(kind, **kw)
        req.rid = self._next_rid
        self._next_rid += 1
        self._reqs[req.rid] = req
        return req

    def _complete_req(self, req: Request, **kw) -> None:
        self._reqs.pop(req.rid, None)
        req._complete(**kw)

    def _complete_rid(self, rid: int, **kw) -> None:
        req = self._reqs.pop(rid, None)
        if req is not None:
            req._complete(**kw)

    # ------------------------------------------------------------- public
    def isend(self, dest: int, data: np.ndarray, tag: int = 0,
              datatype=None) -> Request:
        assert 0 <= dest < self.p.n_ranks, f"bad destination {dest}"
        assert 0 <= tag <= MAX_TAG, f"bad tag {tag}"
        data = np.ascontiguousarray(data)
        if datatype is not None:
            dtype_id = self.registry.resolve(datatype)
            payload = self.registry.pack(dtype_id, data)
        else:
            dtype_id = wire.NO_DTYPE
            payload = _u8view(data).copy()
        req = self._new_request("send", source=self.rank, tag=tag)
        req.nbytes = payload.size
        self.stats["bytes_sent"] += payload.size
        if dest == self.rank:
            env = _Envelope("eager", wire.Ctl(
                wire.FIN_EAGER, src=self.rank, tag=tag, seq=0,
                nbytes=payload.size, dtype_id=dtype_id), payload)
            self._route_envelope(env)
            self._complete_req(req, nbytes=payload.size)
            return req
        mseq = self._mseq_tx.get(dest, 0)
        self._mseq_tx[dest] = mseq + 1
        use_rdv = (dtype_id != wire.NO_DTYPE
                   and payload.size >= self.p.eager_threshold)
        if use_rdv:
            self._rdv_queue.setdefault(dest, deque()).append(dict(
                rid=req.rid, dest=dest, payload=payload,
                dtype_id=dtype_id, tag=tag, mseq=mseq))
            self._pump_rdv(dest)
        else:
            assert payload.size <= self.p.eager_slot_bytes, (
                f"eager message of {payload.size}B exceeds the "
                f"{self.p.eager_slot_bytes}B staging slot — register the "
                f"datatype for rendezvous or raise eager_slot_bytes")
            seq = self._eager_seq.get(dest, 0)
            self._eager_seq[dest] = seq + 1
            self._eager_queue.setdefault(dest, deque()).append(dict(
                rid=req.rid, dest=dest, seq=seq, payload=payload,
                dtype_id=dtype_id, tag=tag, mseq=mseq))
        return req

    def irecv(self, buf: np.ndarray, source: int = ANY_SOURCE,
              tag: int = ANY_TAG, buf_id: Optional[int] = None) -> Request:
        assert source == ANY_SOURCE or 0 <= source < self.p.n_ranks
        req = self._new_request("recv", buf=buf, source=source, tag=tag)
        req.buf_id = buf_id
        env = self._match_unexpected(source, tag)
        if env is None:
            self._posted.append(req)
        elif env.kind == "eager":
            self._deliver_eager(req, env.ctl, env.payload)
        else:
            self._grant_rdv(req, env.ctl)
        return req

    @property
    def done(self) -> bool:
        return not (any(self._eager_queue.values())
                    or any(self._eager_inflight.values())
                    or any(self._rdv_queue.values())
                    or self._rdv_sends or self._active
                    or self._cts_waiting or not self.ctl.idle)

    @property
    def failed(self) -> bool:
        return bool(self.errors)

    # -------------------------------------------------------- fabric hooks
    def poll(self, now: int) -> List[np.ndarray]:
        self._now = now
        out: List[np.ndarray] = []
        # start eligible queued eager sends (per-destination slot gating:
        # seq's staging slot must be free, i.e. seq - slots_per_src FINed)
        for dest, queue in self._eager_queue.items():
            inflight = self._eager_inflight.setdefault(dest, {})
            while queue:
                ent = queue[0]
                slot_key = (dest, ent["seq"] % self.p.eager_slots_per_src)
                if (len(inflight) >= self.p.eager_slots_per_src
                        or ent["seq"] - self.p.eager_slots_per_src
                        in inflight
                        or now < self._eager_cooldown.get(slot_key, 0)):
                    break
                queue.popleft()
                inflight[ent["seq"]] = ent
                self._launch_eager(ent)
        # rendezvous grants waiting for a receive slot
        while self._cts_waiting and self._slot_available():
            rid, ctl = self._cts_waiting.popleft()
            req = self._reqs.get(rid)
            if req is not None:
                self._grant_rdv(req, ctl)
        # drive the SLMP data senders
        for ent in list(self._active):
            sender: slmp.SlmpSender = ent["sender"]
            out.extend(sender.poll(now))
            if sender.failed:
                self._active.remove(ent)
                msg = (f"rank{self.rank}: SLMP data to rank {ent['dest']} "
                       f"exhausted retries (msg_id={ent['msg_id']:#x})")
                self.errors.append(msg)
                self._complete_rid(ent["rid"], error=msg)
            elif sender.done:
                self._active.remove(ent)
                self.stats["retransmits"] += sender.retransmits
                self._sender_done(ent)
        out.extend(self.ctl.poll(now))
        return out

    def on_host_frames(self, frames: List[np.ndarray], now: int) -> None:
        self._now = now
        for f in frames:
            if len(f) < pkt.SLMP_BASE:
                continue
            if wire.frame_dport(f) == wire.CTRL_PORT:
                self.ctl.on_frame(f, now)
                continue
            ack = wire.parse_slmp_ack(f)
            if ack is None:
                continue
            msg_id, off, peer_mac = ack
            for ent in self._active:
                if (ent["msg_id"] == msg_id
                        and self.p.macs[ent["dest"]] == peer_mac):
                    ent["sender"].on_ack(msg_id, off)
                    break

    # ---------------------------------------------------------- send paths
    def _slmp_cfg(self, dest: int, port: int) -> slmp.SlmpSenderConfig:
        return slmp.SlmpSenderConfig(
            window=self.p.slmp_window, mtu_payload=self.p.mtu_payload,
            timeout=self.p.slmp_timeout,
            max_retries=self.p.slmp_max_retries, port=port,
            src_mac=self.p.macs[self.rank], dst_mac=self.p.macs[dest])

    def _launch_eager(self, ent: dict) -> None:
        dest, seq = ent["dest"], ent["seq"]
        slot = self.rank * self.p.eager_slots_per_src \
            + seq % self.p.eager_slots_per_src
        msg_id = wire.pack_msg_id(wire.MPI_KIND_EAGER, 0, slot)
        sender = slmp.SlmpSender(ent["payload"], msg_id,
                                 self._slmp_cfg(dest, wire.EAGER_PORT))
        self.stats["eager_sent"] += 1
        self._active.append(dict(ent, kind="eager", slot=slot,
                                 msg_id=msg_id, sender=sender))

    def _pump_rdv(self, dest: int) -> None:
        """Launch queued rendezvous sends up to the destination's credit
        window (RTS pipelining: always at least one outstanding probe)."""
        queue = self._rdv_queue.get(dest)
        if not queue:
            return
        window = max(1, self._rdv_window.get(dest, 1))
        while queue and self._rdv_outstanding.get(dest, 0) < window:
            ent = queue.popleft()
            seq = self._msg_seq.get(dest, 0)
            self._msg_seq[dest] = seq + 1
            ent["seq"] = seq
            self._rdv_sends[(dest, seq)] = ent
            self._rdv_outstanding[dest] = \
                self._rdv_outstanding.get(dest, 0) + 1
            self.stats["rdv_sent"] += 1
            self.ctl.send(dest, wire.Ctl(
                wire.RTS, src=self.rank, tag=ent["tag"], seq=seq,
                nbytes=ent["payload"].size, dtype_id=ent["dtype_id"],
                mseq=ent["mseq"]))
        if queue:
            self.stats["window_stalls"] += 1

    def _on_cts(self, ctl: wire.Ctl) -> None:
        # the grant carries the receiver's remaining credit: resize the
        # RTS pipeline window toward it (the granted transfer itself is
        # still outstanding, hence the +1)
        self._rdv_window[ctl.src] = max(1, ctl.credit + 1)
        ent = self._rdv_sends.pop((ctl.src, ctl.seq), None)
        if ent is None:
            return                              # stale duplicate
        msg_id = wire.pack_msg_id(wire.MPI_KIND_RDV, ent["dtype_id"],
                                  ctl.slot)
        sender = slmp.SlmpSender(ent["payload"], msg_id,
                                 self._slmp_cfg(ent["dest"], wire.DATA_PORT))
        self._active.append(dict(ent, kind="rdv", slot=ctl.slot, mseq=0,
                                 msg_id=msg_id, sender=sender))
        self._pump_rdv(ctl.src)

    def _sender_done(self, ent: dict) -> None:
        """An SLMP data transfer fully ACKed: send the FIN whose ack token
        completes the request (eager additionally frees its staging slot)."""
        nbytes = int(ent["payload"].size)
        if ent["kind"] == "eager":
            fin = wire.Ctl(wire.FIN_EAGER, src=self.rank, tag=ent["tag"],
                           seq=ent["seq"], nbytes=nbytes,
                           dtype_id=ent["dtype_id"], slot=ent["slot"],
                           mseq=ent["mseq"])
            token = ("eafin", ent["dest"], ent["seq"], ent["rid"], nbytes)
        else:
            fin = wire.Ctl(wire.FIN_RDV, src=self.rank, tag=ent["tag"],
                           seq=ent["seq"], nbytes=nbytes,
                           dtype_id=ent["dtype_id"], slot=ent["slot"])
            token = ("rdvfin", ent["rid"], nbytes, ent["dest"])
        self.ctl.send(ent["dest"], fin, token=token)

    def _on_tok_acked(self, tok: tuple) -> None:
        """Dispatch a control-ack continuation token (plain data)."""
        if tok[0] == "eafin":
            _, dest, seq, rid, nbytes = tok
            self._eager_inflight.get(dest, {}).pop(seq, None)
            self._eager_cooldown[(dest, seq % self.p.eager_slots_per_src)] \
                = self._now + self.p.slot_quarantine
            self._complete_rid(rid, nbytes=nbytes)
        elif tok[0] == "rdvfin":
            _, rid, nbytes, dest = tok
            self._rdv_outstanding[dest] = \
                max(0, self._rdv_outstanding.get(dest, 0) - 1)
            self._complete_rid(rid, nbytes=nbytes)
            self._pump_rdv(dest)

    # ------------------------------------------------------- receive paths
    def _on_ctl_give_up(self, dst: int, body: wire.Ctl) -> None:
        self.errors.append(
            f"rank{self.rank}: control message kind={body.kind} to rank "
            f"{dst} (tag={body.tag}, seq={body.seq}) exhausted "
            f"{self.p.ctl_max_retries} retries")

    def _on_ctl(self, ctl: wire.Ctl, now: int) -> None:
        self._now = now
        if ctl.kind == wire.CTS:
            self._on_cts(ctl)
        elif ctl.kind == wire.RTS:
            self._enqueue_matching(_Envelope("rts", ctl))
        elif ctl.kind == wire.FIN_EAGER:
            slot = ctl.src * self.p.eager_slots_per_src \
                + ctl.seq % self.p.eager_slots_per_src
            base = self.p.eager_base + slot * self.p.eager_slot_bytes
            payload = np.array(self._node.read_host(base, ctl.nbytes),
                               np.uint8)
            self._enqueue_matching(_Envelope("eager", ctl, payload))
        elif ctl.kind == wire.FIN_RDV:
            self._finish_rdv_recv(ctl)

    def _enqueue_matching(self, env: _Envelope) -> None:
        """Admit wire envelopes to tag matching in per-sender send order
        (mseq) — MPI's non-overtaking guarantee.  An envelope whose
        predecessors have not arrived waits here."""
        src = env.ctl.src
        pending = self._mseq_pending.setdefault(src, {})
        pending[env.ctl.mseq] = env
        expected = self._mseq_rx.get(src, 0)
        while expected in pending:
            self._route_envelope(pending.pop(expected))
            expected += 1
        self._mseq_rx[src] = expected

    def _route_envelope(self, env: _Envelope) -> None:
        req = self._match_posted(env.ctl.src, env.ctl.tag)
        if req is None:
            self.stats["unexpected"] += 1
            self._unexpected.append(env)
        elif env.kind == "eager":
            self._deliver_eager(req, env.ctl, env.payload)
        else:
            self._grant_rdv(req, env.ctl)

    def _match_posted(self, src: int, tag: int) -> Optional[Request]:
        for i, req in enumerate(self._posted):
            if ((req.source in (ANY_SOURCE, src))
                    and (req.tag in (ANY_TAG, tag))):
                return self._posted.pop(i)
        return None

    def _match_unexpected(self, source: int, tag: int
                          ) -> Optional[_Envelope]:
        for i, env in enumerate(self._unexpected):
            if ((source in (ANY_SOURCE, env.ctl.src))
                    and (tag in (ANY_TAG, env.ctl.tag))):
                del self._unexpected[i]
                return env
        return None

    def _deliver_eager(self, req: Request, ctl: wire.Ctl,
                       payload: np.ndarray) -> None:
        view = _u8view(req.buf)
        if ctl.dtype_id != wire.NO_DTYPE:
            self.registry.unpack_into(ctl.dtype_id, payload, req.buf)
        else:
            assert view.size >= ctl.nbytes, (
                f"recv buffer {view.size}B < message {ctl.nbytes}B")
            view[:ctl.nbytes] = payload[:ctl.nbytes]
        self.stats["bytes_recv"] += ctl.nbytes
        self._complete_req(req, source=ctl.src, tag=ctl.tag,
                           nbytes=ctl.nbytes)

    # --- rendezvous receive (credit-managed, generation-armed slots)
    def _slot_available(self) -> bool:
        return bool(self._free_slots)

    def _grant_rdv(self, req: Request, ctl: wire.Ctl) -> None:
        if not self._slot_available():
            # no lease: the grant queues until a slot FINs
            self.stats["credit_stalls"] += 1
            self._cts_waiting.append((req.rid, ctl))
            return
        phys = self._free_slots.pop()
        mem_bytes = self.registry.mem_bytes(ctl.dtype_id)
        assert mem_bytes <= self.p.rdv_region_bytes
        assert _u8view(req.buf).size >= mem_bytes, (
            f"recv buffer {req.buf.size}B < datatype extent {mem_bytes}B")
        # virtual slot = generation · n_slots + phys (16-bit wire field);
        # arm the NIC with the exact msg_id before the sender learns the
        # slot — frames of any other occupant are dropped on the device
        gens = max(1, (1 << 16) // self.p.n_rdv_slots)
        vslot = (self._slot_gen[phys] % gens) * self.p.n_rdv_slots + phys
        self._node.write_expect(
            phys, wire.pack_msg_id(wire.MPI_KIND_RDV, ctl.dtype_id, vslot))
        self._rdv_recv[vslot] = (req.rid, ctl)
        self.ctl.send(ctl.src, wire.Ctl(
            wire.CTS, src=self.rank, tag=ctl.tag, seq=ctl.seq,
            nbytes=ctl.nbytes, dtype_id=ctl.dtype_id, slot=vslot,
            credit=len(self._free_slots)))

    def _finish_rdv_recv(self, fin: wire.Ctl) -> None:
        entry = self._rdv_recv.pop(fin.slot, None)
        if entry is None:
            return                              # duplicate FIN
        rid, rts = entry
        req = self._reqs.get(rid)
        phys = fin.slot % self.p.n_rdv_slots
        if req is not None:
            base = self.p.rdv_base + phys * self.p.rdv_region_bytes
            mem_bytes = self.registry.mem_bytes(rts.dtype_id)
            window = np.array(self._node.read_host(base, mem_bytes),
                              np.uint8)
            mask = self.registry.mem_mask(rts.dtype_id)
            view = _u8view(req.buf)
            # the NIC already unpacked: copy only the bytes the datatype
            # wrote (holes keep the buffer's contents — MPI unpack)
            view[:mem_bytes][mask] = window[mask]
        # disarm and recycle the slot immediately: late duplicates of this
        # (or any earlier) occupant no longer match the expect table
        self._node.write_expect(phys, 0)
        self._slot_gen[phys] += 1
        self._free_slots.append(phys)
        self.stats["bytes_recv"] += fin.nbytes
        if req is not None:
            self._complete_req(req, source=rts.src, tag=rts.tag,
                               nbytes=fin.nbytes)

    # ----------------------------------------------------------- checkpoint
    def _snap_ent(self, ent: dict) -> dict:
        """Plain copy of a send-side entry (without any live sender)."""
        out = {k: v for k, v in ent.items() if k != "sender"}
        out["payload"] = ent["payload"].copy()
        return out

    def _snap_request(self, req: Request) -> dict:
        if req.buf is None:
            buf = None
        elif req.buf_id is not None and self.pool is not None \
                and self.pool.has(req.buf_id):
            buf = ("pool", req.buf_id)
        else:
            # aliasing into user arrays cannot survive a fresh object
            # graph: the restored request owns a copy (read results off
            # the request / the restored plan, not the original array)
            buf = ("copy", np.array(req.buf))
        return dict(rid=req.rid, kind=req.kind, source=req.source,
                    tag=req.tag, nbytes=req.nbytes, ctoken=req.ctoken,
                    buf=buf)

    def _restore_request(self, s: dict) -> Request:
        buf = None
        buf_id = None
        if s["buf"] is not None:
            how, val = s["buf"]
            if how == "pool":
                assert self.pool is not None, \
                    "pool-bound request needs a BufferPool to restore into"
                buf, buf_id = self.pool.get(val), val
            else:
                buf = np.array(val)
        req = Request(s["kind"], buf=buf, source=s["source"], tag=s["tag"])
        req.nbytes = s["nbytes"]
        req.rid = s["rid"]
        req.buf_id = buf_id
        req.ctoken = None if s["ctoken"] is None else \
            (s["ctoken"][0], tuple(s["ctoken"][1]))
        return req

    def snapshot(self) -> dict:
        ctl_t = dataclasses.astuple
        return dict(
            now=self._now,
            next_rid=self._next_rid,
            requests=[self._snap_request(r) for r in self._reqs.values()],
            eager_seq=list(self._eager_seq.items()),
            msg_seq=list(self._msg_seq.items()),
            mseq_tx=list(self._mseq_tx.items()),
            eager_queue=[(d, [self._snap_ent(e) for e in q])
                         for d, q in self._eager_queue.items()],
            eager_inflight=[(d, [(s, self._snap_ent(e))
                                 for s, e in m.items()])
                            for d, m in self._eager_inflight.items()],
            eager_cooldown=list(self._eager_cooldown.items()),
            rdv_sends=[(k, self._snap_ent(e))
                       for k, e in self._rdv_sends.items()],
            rdv_queue=[(d, [self._snap_ent(e) for e in q])
                       for d, q in self._rdv_queue.items()],
            rdv_window=list(self._rdv_window.items()),
            rdv_outstanding=list(self._rdv_outstanding.items()),
            active=[dict(self._snap_ent(e),
                         sender=e["sender"].snapshot())
                    for e in self._active],
            posted=[r.rid for r in self._posted],
            unexpected=[_env_snap(e) for e in self._unexpected],
            mseq_rx=list(self._mseq_rx.items()),
            mseq_pending=[(s, [(m, _env_snap(e)) for m, e in p.items()])
                          for s, p in self._mseq_pending.items()],
            rdv_recv=[(slot, rid, ctl_t(c))
                      for slot, (rid, c) in self._rdv_recv.items()],
            free_slots=list(self._free_slots),
            slot_gen=list(self._slot_gen),
            cts_waiting=[(rid, ctl_t(c)) for rid, c in self._cts_waiting],
            stats=dict(self.stats),
            errors=list(self.errors),
            ctl=self.ctl.snapshot(),
        )

    def restore(self, snap: dict) -> None:
        self._now = snap["now"]
        self._next_rid = snap["next_rid"]
        self._reqs = {}
        for rs in snap["requests"]:
            req = self._restore_request(rs)
            self._reqs[req.rid] = req
        self._eager_seq = dict(snap["eager_seq"])
        self._msg_seq = dict(snap["msg_seq"])
        self._mseq_tx = dict(snap["mseq_tx"])
        self._eager_queue = {
            d: deque(self._snap_ent(e) for e in q)
            for d, q in snap["eager_queue"]}
        self._eager_inflight = {
            d: {s: self._snap_ent(e) for s, e in m}
            for d, m in snap["eager_inflight"]}
        self._eager_cooldown = dict(snap["eager_cooldown"])
        self._rdv_sends = {tuple(k): self._snap_ent(e)
                           for k, e in snap["rdv_sends"]}
        self._rdv_queue = {d: deque(self._snap_ent(e) for e in q)
                           for d, q in snap["rdv_queue"]}
        self._rdv_window = dict(snap["rdv_window"])
        self._rdv_outstanding = dict(snap["rdv_outstanding"])
        self._active = []
        for es in snap["active"]:
            ent = {k: v for k, v in es.items() if k != "sender"}
            ent["payload"] = es["payload"].copy()
            port = wire.EAGER_PORT if ent["kind"] == "eager" \
                else wire.DATA_PORT
            sender = slmp.SlmpSender(ent["payload"], ent["msg_id"],
                                     self._slmp_cfg(ent["dest"], port))
            sender.restore(es["sender"])
            ent["sender"] = sender
            self._active.append(ent)
        self._posted = [self._reqs[rid] for rid in snap["posted"]]
        self._unexpected = deque(_env_restore(t) for t in snap["unexpected"])
        self._mseq_rx = dict(snap["mseq_rx"])
        self._mseq_pending = {
            s: {m: _env_restore(t) for m, t in p}
            for s, p in snap["mseq_pending"]}
        self._rdv_recv = {slot: (rid, wire.Ctl(*c))
                          for slot, rid, c in snap["rdv_recv"]}
        self._free_slots = list(snap["free_slots"])
        self._slot_gen = list(snap["slot_gen"])
        self._cts_waiting = deque((rid, wire.Ctl(*c))
                                  for rid, c in snap["cts_waiting"])
        self.stats = dict(snap["stats"])
        self.errors = list(snap["errors"])
        self.ctl.restore(snap["ctl"])
