"""repro.mpi — MPI point-to-point and collectives over the fabric, with
receive-side datatype processing offloaded to the SpinNIC (paper §V-C as a
real multi-node experiment).

  wire.py          envelopes, msg_id packing, reliable control datagrams
  datatypes.py     committed-datatype registry (job-wide commit cache)
  engine.py        per-rank host engine: tag matching, eager/rendezvous,
                   closure-free checkpointable protocol state
  communicator.py  ranks ↔ fabric nodes, requests, progress, checkpoint
  collectives.py   nonblocking plan-based collectives: binomial trees,
                   recursive-doubling allreduce, Bruck alltoall(v)

Quick taste::

    from repro import mpi
    from repro.core import ddt

    reg = mpi.DatatypeRegistry()
    col = reg.register(ddt.Vector(64, 1, 8, ddt.MPI_FLOAT), count=1)
    comm = mpi.Communicator(4, registry=reg)
    r = comm.irecv(1, buf, source=mpi.ANY_SOURCE, tag=7)
    s = comm.isend(0, 1, data, tag=7, datatype=col)   # NIC unpacks
    h = mpi.iallreduce(comm, vals)                    # log-step plan
    while not h.test():
        compute_something(); comm.progress()          # real overlap
    comm.waitall([r, s, h])
"""
from repro.mpi.collectives import (ALLREDUCE_RAB_MIN_BYTES,
                                   ALLREDUCE_RD_MAX_BYTES,
                                   ALLTOALL_BRUCK_MAX_BLOCK,
                                   BCAST_PIPELINE_MIN_BYTES, CollRequest,
                                   allreduce, alltoall, alltoallv, barrier,
                                   bcast, iallreduce, ialltoall, ialltoallv,
                                   ibarrier, ibcast, ireduce, reduce)
from repro.mpi.communicator import (COLL_TAG_BASE, BufferPool, Communicator,
                                    MpiConfig, PersistentRequest,
                                    clear_nic_cache)
from repro.mpi.datatypes import (COMMIT_COUNTERS, DatatypeRegistry,
                                 clear_commit_cache)
from repro.mpi.engine import ANY_SOURCE, ANY_TAG, MpiHostEngine, Request
from repro.mpi.wire import CTRL_PORT, DATA_PORT, EAGER_PORT

__all__ = ["Communicator", "MpiConfig", "DatatypeRegistry", "MpiHostEngine",
           "Request", "CollRequest", "BufferPool", "PersistentRequest",
           "ANY_SOURCE", "ANY_TAG",
           "bcast", "reduce", "allreduce", "alltoall", "alltoallv",
           "barrier", "ibcast", "ireduce", "iallreduce", "ialltoall",
           "ialltoallv", "ibarrier", "COLL_TAG_BASE",
           "ALLREDUCE_RD_MAX_BYTES", "ALLREDUCE_RAB_MIN_BYTES",
           "BCAST_PIPELINE_MIN_BYTES", "ALLTOALL_BRUCK_MAX_BLOCK",
           "COMMIT_COUNTERS", "clear_commit_cache", "clear_nic_cache",
           "EAGER_PORT", "DATA_PORT", "CTRL_PORT"]
