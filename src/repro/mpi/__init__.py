"""repro.mpi — MPI point-to-point and collectives over the fabric, with
receive-side datatype processing offloaded to the SpinNIC (paper §V-C as a
real multi-node experiment).

  wire.py          envelopes, msg_id packing, reliable control datagrams
  datatypes.py     committed-datatype registry (dataloop commit + tables)
  engine.py        per-rank host engine: tag matching, eager/rendezvous
  communicator.py  ranks ↔ fabric nodes, requests, progress
  collectives.py   bcast / reduce / allreduce / alltoall(v) / barrier

Quick taste::

    from repro import mpi
    from repro.core import ddt

    reg = mpi.DatatypeRegistry()
    col = reg.register(ddt.Vector(64, 1, 8, ddt.MPI_FLOAT), count=1)
    comm = mpi.Communicator(4, registry=reg)
    r = comm.irecv(1, buf, source=mpi.ANY_SOURCE, tag=7)
    s = comm.isend(0, 1, data, tag=7, datatype=col)   # NIC unpacks
    comm.wait(r, s)
"""
from repro.mpi.collectives import (allreduce, alltoall, alltoallv, barrier,
                                   bcast, reduce)
from repro.mpi.communicator import Communicator, MpiConfig
from repro.mpi.datatypes import DatatypeRegistry
from repro.mpi.engine import ANY_SOURCE, ANY_TAG, MpiHostEngine, Request
from repro.mpi.wire import CTRL_PORT, DATA_PORT, EAGER_PORT

__all__ = ["Communicator", "MpiConfig", "DatatypeRegistry", "MpiHostEngine",
           "Request", "ANY_SOURCE", "ANY_TAG", "bcast", "reduce",
           "allreduce", "alltoall", "alltoallv", "barrier",
           "EAGER_PORT", "DATA_PORT", "CTRL_PORT"]
