"""Committed-datatype registry shared by all ranks of a communicator.

MPI requires types to be *committed* before use; here commitment runs the
dataloop specialization of :mod:`repro.core.ddt` (flatten → byte index
maps) and additionally uploads every committed map into one padded device
table, so the NIC-side unpack handler
(:func:`repro.core.apps.make_mpi_ddt_context`) can select the right map
per message from the dtype id carried in the SLMP msg_id.  Like real MPI
type commitment under SPMD, the registry must be identical on every rank
— one registry object is shared by all nodes of a communicator.

The registry also owns the *host-side* pack/unpack paths: senders pack on
the host (the paper offloads the receive side), and eager receives fall
back to host unpack — the comparison baseline for the offload benchmark.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core import ddt as ddtlib

DTypeLike = Union[int, ddtlib.DDT, Tuple[ddtlib.DDT, int]]

# Job-wide commit cache: committing a datatype (dataloop flatten -> byte
# index maps) is pure in (ddt, count), and DDT constructors are frozen
# dataclasses, so one commit per distinct pair serves every registry in
# the process.  Two communicators registering the same (ddt, count) share
# one CommittedDDT — the NIC index map is built once per job, not once
# per registry.  CommittedDDT arrays are treated as immutable.
_COMMIT_CACHE: Dict[Tuple[ddtlib.DDT, int], ddtlib.CommittedDDT] = {}
COMMIT_COUNTERS = dict(commits=0, hits=0)


def cached_commit(ddt: ddtlib.DDT, count: int) -> ddtlib.CommittedDDT:
    """Commit ``count`` instances of ``ddt``, memoized per job."""
    key = (ddt, count)
    c = _COMMIT_CACHE.get(key)
    if c is None:
        COMMIT_COUNTERS["commits"] += 1
        c = ddtlib.commit(ddt, count)
        _COMMIT_CACHE[key] = c
    else:
        COMMIT_COUNTERS["hits"] += 1
    return c


def clear_commit_cache() -> None:
    """Testing hook: drop the job-wide cache and zero the counters."""
    _COMMIT_CACHE.clear()
    COMMIT_COUNTERS["commits"] = 0
    COMMIT_COUNTERS["hits"] = 0


class DatatypeRegistry:
    def __init__(self):
        self._committed: List[ddtlib.CommittedDDT] = []
        self._names: List[str] = []
        self._frozen = False

    def __len__(self) -> int:
        return len(self._committed)

    def register(self, ddt: ddtlib.DDT, count: int = 1,
                 name: Optional[str] = None) -> int:
        """Commit ``count`` instances of ``ddt``; returns the dtype id."""
        assert not self._frozen, \
            "registry is frozen (a Communicator was already built on it)"
        c = cached_commit(ddt, count)
        assert c.msg_bytes > 0, "cannot register an empty datatype"
        self._committed.append(c)
        self._names.append(name or f"dtype{len(self._committed) - 1}")
        return len(self._committed) - 1

    def freeze(self) -> None:
        self._frozen = True

    def resolve(self, dtype: DTypeLike) -> int:
        """Accept a dtype id, a registered DDT (count=1), or (DDT, count)."""
        if isinstance(dtype, int):
            assert 0 <= dtype < len(self._committed), f"bad dtype id {dtype}"
            return dtype
        ddt, count = dtype if isinstance(dtype, tuple) else (dtype, 1)
        for i, c in enumerate(self._committed):
            if c.ddt == ddt and c.count == count:
                return i
        raise KeyError(f"datatype {ddt}×{count} not registered")

    def committed(self, dtype_id: int) -> ddtlib.CommittedDDT:
        return self._committed[dtype_id]

    def name(self, dtype_id: int) -> str:
        return self._names[dtype_id]

    def msg_bytes(self, dtype_id: int) -> int:
        return self._committed[dtype_id].msg_bytes

    def mem_bytes(self, dtype_id: int) -> int:
        return self._committed[dtype_id].mem_bytes

    def mem_mask(self, dtype_id: int) -> np.ndarray:
        """(mem_bytes,) bool — bytes the datatype actually writes."""
        return self._committed[dtype_id].mem_to_msg >= 0

    # ------------------------------------------------------- device tables
    @property
    def max_mem_bytes(self) -> int:
        return max((c.mem_bytes for c in self._committed), default=0)

    def tables(self) -> Tuple[np.ndarray, np.ndarray]:
        """(maps, msg_lens) for the NIC unpack handler: maps (D, Mmax)
        int32 msg→mem byte offsets padded with -1, msg_lens (D,) int32.

        Overlapping layouts are *deduplicated*: a message byte that is not
        the last serialized occurrence of its memory byte maps to -1 (DMA
        skip).  Packets then commute — MPI's last-occurrence-wins unpack
        holds regardless of segment arrival/retransmission order on the
        lossy wire, with every memory byte written exactly once."""
        n = len(self._committed)
        mmax = max(max((c.msg_bytes for c in self._committed), default=0), 1)
        maps = np.full((max(n, 1), mmax), -1, np.int32)
        lens = np.zeros((max(n, 1),), np.int32)
        for i, c in enumerate(self._committed):
            winner = c.mem_to_msg[c.msg_to_mem] == np.arange(
                c.msg_bytes, dtype=np.int32)
            maps[i, :c.msg_bytes] = np.where(winner, c.msg_to_mem, -1)
            lens[i] = c.msg_bytes
        return maps, lens

    # --------------------------------------------------- host (un)pack path
    def pack(self, dtype_id: int, mem: np.ndarray) -> np.ndarray:
        """Serialize from a memory-layout uint8 buffer (sender side)."""
        c = self._committed[dtype_id]
        mem = np.ascontiguousarray(mem).reshape(-1).view(np.uint8)
        assert mem.size >= c.mem_bytes, \
            f"send buffer {mem.size}B < datatype extent {c.mem_bytes}B"
        return ddtlib.pack_np(c, mem[:c.mem_bytes])

    def unpack_into(self, dtype_id: int, msg: np.ndarray,
                    mem: np.ndarray) -> None:
        """Host-side unpack (eager fallback): scatter serialized bytes into
        ``mem`` in serialization order — last occurrence wins on overlap."""
        c = self._committed[dtype_id]
        view = mem.reshape(-1).view(np.uint8)
        assert view.size >= c.mem_bytes
        view[c.msg_to_mem] = msg[:c.msg_bytes]
