"""Wire formats of the MPI messaging layer.

Three UDP ports per rank, all carried over the fabric:

  * ``EAGER_PORT`` — SLMP data, matched by the NIC eager context
    (:func:`repro.core.apps.make_mpi_eager_context`): small messages,
    reassembled into per-sender staging slots of the host window.
  * ``DATA_PORT`` — SLMP data, matched by the NIC DDT-unpack context:
    rendezvous payloads, scattered through the committed datatype map
    straight into the posted receive region (the §V-C offload).
  * ``CTRL_PORT`` — plain UDP control datagrams (RTS / CTS / FIN).  These
    match no execution context, so they take the Corundum/host datapath
    and are consumed by the host engine — exactly where MPI's matching
    logic lives on a real FPsPIN host.

The wire is lossy, so control datagrams get their own reliability:
:class:`CtlEndpoint` is a tiny ack/retransmit/dedup layer (per-peer
sequence numbers, at-most-once delivery to the engine).  SLMP data needs
none of this — the SLMP sender state machine already retransmits.

msg_id packing for SLMP data messages re-exports the NIC-side constants
from :mod:`repro.core.apps` — host library and NIC handlers must agree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import packet as pkt
from repro.core.apps import (MPI_KIND_EAGER, MPI_KIND_RDV,
                             MPI_MSGID_DTYPE_MASK, MPI_MSGID_DTYPE_SHIFT,
                             MPI_MSGID_KIND_SHIFT, MPI_MSGID_SLOT_MASK)

EAGER_PORT = 9340
DATA_PORT = 9341
CTRL_PORT = 9350

ANY_SOURCE = -1
ANY_TAG = -1

NO_DTYPE = 0xFFFF            # dtype_id wire value for raw-byte messages

# control transport kinds
CTL_MSG = 1
CTL_ACK = 2
CTL_HDR_BYTES = 7            # kind u8 | src u16 | ctl_seq u32

# control message (body) kinds
RTS = 1                      # rendezvous request-to-send
CTS = 2                      # rendezvous clear-to-send (carries the slot)
FIN_EAGER = 3                # eager message fully ACKed: envelope delivery
FIN_RDV = 4                  # rendezvous payload fully ACKed
BODY_BYTES = 25              # kind u8 | src u16 | tag u32 | seq u32 |
#                              nbytes u32 | dtype u16 | slot u16 | mseq u32 |
#                              credit u16


def pack_msg_id(kind: int, dtype_id: int, slot: int) -> int:
    """SLMP msg_id encoding read back by the NIC handlers (28-bit)."""
    assert 0 <= slot <= MPI_MSGID_SLOT_MASK
    assert 0 <= dtype_id <= MPI_MSGID_DTYPE_MASK
    return (kind << MPI_MSGID_KIND_SHIFT) | (dtype_id << MPI_MSGID_DTYPE_SHIFT) \
        | slot


def unpack_msg_id(msg_id: int) -> Tuple[int, int, int]:
    return ((msg_id >> MPI_MSGID_KIND_SHIFT) & 0xF,
            (msg_id >> MPI_MSGID_DTYPE_SHIFT) & MPI_MSGID_DTYPE_MASK,
            msg_id & MPI_MSGID_SLOT_MASK)


# --------------------------------------------------------------- envelopes
@dataclasses.dataclass(frozen=True)
class Ctl:
    """One MPI control message (the body of a reliable control datagram)."""
    kind: int                # RTS | CTS | FIN_EAGER | FIN_RDV
    src: int                 # rank of the *message* originator
    tag: int
    seq: int                 # per-protocol sequence (eager slot / CTS key)
    nbytes: int              # serialized payload size
    dtype_id: int = NO_DTYPE
    slot: int = 0
    mseq: int = 0            # per (src, dst) *matching* sequence: RTS and
    #                          FIN_EAGER must enter tag matching in send
    #                          order (MPI non-overtaking), regardless of
    #                          which control datagram lands first
    credit: int = 0          # CTS: receiver's remaining free rendezvous
    #                          slot leases after this grant — the sender
    #                          sizes its per-destination RTS pipeline
    #                          window from it (end-to-end flow control)


def encode_body(c: Ctl) -> np.ndarray:
    b = np.zeros(BODY_BYTES, np.uint8)
    b[0] = c.kind
    b[1:3] = divmod(c.src, 256)[0], c.src & 0xFF
    b[3:7] = np.frombuffer(int(c.tag).to_bytes(4, "big"), np.uint8)
    b[7:11] = np.frombuffer(int(c.seq).to_bytes(4, "big"), np.uint8)
    b[11:15] = np.frombuffer(int(c.nbytes).to_bytes(4, "big"), np.uint8)
    b[15:17] = divmod(c.dtype_id, 256)[0], c.dtype_id & 0xFF
    b[17:19] = divmod(c.slot, 256)[0], c.slot & 0xFF
    b[19:23] = np.frombuffer(int(c.mseq).to_bytes(4, "big"), np.uint8)
    b[23:25] = divmod(c.credit, 256)[0], c.credit & 0xFF
    return b


def decode_body(b: np.ndarray) -> Ctl:
    def u16(o):
        return (int(b[o]) << 8) | int(b[o + 1])

    def u32(o):
        return int.from_bytes(bytes(b[o:o + 4]), "big")

    return Ctl(kind=int(b[0]), src=u16(1), tag=u32(3), seq=u32(7),
               nbytes=u32(11), dtype_id=u16(15), slot=u16(17),
               mseq=u32(19), credit=u16(23))


def _u16(frame: np.ndarray, off: int) -> int:
    return (int(frame[off]) << 8) | int(frame[off + 1])


def frame_dport(frame: np.ndarray) -> int:
    return _u16(frame, pkt.UDP_DPORT)


def parse_slmp_ack(frame: np.ndarray
                   ) -> Optional[Tuple[int, int, bytes]]:
    """If ``frame`` is an SLMP ACK, return (msg_id, offset, peer_mac) —
    peer_mac (the frame's ETH_SRC) disambiguates senders that reuse a
    msg_id toward different destinations."""
    if len(frame) < pkt.SLMP_PAYLOAD:
        return None
    flags = _u16(frame, pkt.SLMP_FLAGS)
    if not flags & pkt.SLMP_FLAG_ACK:
        return None
    msg_id = int.from_bytes(bytes(frame[pkt.SLMP_MSGID:pkt.SLMP_MSGID + 4]),
                            "big")
    off = int.from_bytes(bytes(frame[pkt.SLMP_OFFSET:pkt.SLMP_OFFSET + 4]),
                         "big")
    return msg_id, off, bytes(frame[pkt.ETH_SRC:pkt.ETH_SRC + 6])


# ------------------------------------------------------- reliable control
class CtlEndpoint:
    """Reliable, deduplicated control datagrams over the lossy wire.

    Every outgoing :class:`Ctl` gets a per-destination ``ctl_seq`` and is
    retransmitted until the peer's CTL_ACK arrives; incoming datagrams are
    ACKed always and delivered to ``self.deliver`` at most once.  This is
    the host-side analogue of SLMP's per-segment reliability, sized for
    single-frame control traffic.

    Ack continuations are *tokens* (plain tuples dispatched through
    ``self.on_acked``), not closures, so the whole endpoint — including
    in-flight messages and their continuations — round-trips through
    :meth:`snapshot` / :meth:`restore` for fabric checkpointing.
    """

    def __init__(self, rank: int, macs: List[bytes], timeout: int = 12,
                 max_retries: int = 400):
        self.rank = rank
        self.macs = macs
        self.timeout = timeout
        self.max_retries = max_retries
        self.deliver: Optional[Callable[[Ctl, int], None]] = None
        # dispatcher for ack tokens (set by the owning engine)
        self.on_acked: Optional[Callable[[tuple], None]] = None
        # called when a message exhausts its retries — the owner must
        # surface this as a hard failure (a silently dropped RTS/CTS/FIN
        # would otherwise hang its request until a generic timeout)
        self.on_give_up: Optional[Callable[[int, Ctl], None]] = None
        self._next_seq: Dict[int, int] = {}
        # (dst, ctl_seq) -> [frame, last_sent, retries, token, body]
        self._unacked: Dict[Tuple[int, int], list] = {}
        self._seen: Dict[int, Set[int]] = {}
        self._ack_outbox: List[np.ndarray] = []
        self.give_ups = 0

    @property
    def idle(self) -> bool:
        return not self._unacked and not self._ack_outbox

    def send(self, dst: int, body: Ctl,
             token: Optional[tuple] = None) -> None:
        seq = self._next_seq.get(dst, 0)
        self._next_seq[dst] = seq + 1
        hdr = np.zeros(CTL_HDR_BYTES, np.uint8)
        hdr[0] = CTL_MSG
        hdr[1:3] = (self.rank >> 8) & 0xFF, self.rank & 0xFF
        hdr[3:7] = np.frombuffer(int(seq).to_bytes(4, "big"), np.uint8)
        frame = pkt.make_udp(np.concatenate([hdr, encode_body(body)]),
                             sport=CTRL_PORT, dport=CTRL_PORT,
                             src_mac=self.macs[self.rank],
                             dst_mac=self.macs[dst])
        self._unacked[(dst, seq)] = [frame, None, 0, token, body]

    def poll(self, now: int) -> List[np.ndarray]:
        out = self._ack_outbox
        self._ack_outbox = []
        for key, ent in list(self._unacked.items()):
            frame, last_sent, retries, _, body = ent
            if last_sent is not None and now - last_sent < self.timeout:
                continue
            if last_sent is not None:
                if retries >= self.max_retries:
                    del self._unacked[key]
                    self.give_ups += 1
                    if self.on_give_up is not None:
                        self.on_give_up(key[0], body)
                    continue
                ent[2] = retries + 1
            ent[1] = now
            out.append(frame)
        return out

    def on_frame(self, frame: np.ndarray, now: int) -> None:
        p = frame[pkt.SLMP_BASE:]                 # UDP payload
        if len(p) < CTL_HDR_BYTES:
            return
        kind = int(p[0])
        src = (int(p[1]) << 8) | int(p[2])
        seq = int.from_bytes(bytes(p[3:7]), "big")
        if kind == CTL_ACK:
            ent = self._unacked.pop((src, seq), None)
            if ent is not None and ent[3] is not None \
                    and self.on_acked is not None:
                self.on_acked(ent[3])              # dispatch the ack token
            return
        if kind != CTL_MSG or len(p) < CTL_HDR_BYTES + BODY_BYTES:
            return
        # always ACK (the first ACK may have been lost)
        ack = np.zeros(CTL_HDR_BYTES, np.uint8)
        ack[0] = CTL_ACK
        ack[1:3] = (self.rank >> 8) & 0xFF, self.rank & 0xFF
        ack[3:7] = p[3:7]
        self._ack_outbox.append(pkt.make_udp(
            ack, sport=CTRL_PORT, dport=CTRL_PORT,
            src_mac=self.macs[self.rank], dst_mac=self.macs[src]))
        seen = self._seen.setdefault(src, set())
        if seq in seen:
            return                                 # duplicate: ACKed only
        seen.add(seq)
        body = decode_body(p[CTL_HDR_BYTES:CTL_HDR_BYTES + BODY_BYTES])
        if self.deliver is not None:
            self.deliver(body, now)

    # ----------------------------------------------------------- checkpoint
    def snapshot(self) -> dict:
        """Full endpoint state as plain data (insertion orders preserved —
        retransmission order is part of fabric determinism)."""
        return dict(
            next_seq=list(self._next_seq.items()),
            unacked=[(dst, seq, frame.copy(), last, retries, token,
                      dataclasses.astuple(body))
                     for (dst, seq), (frame, last, retries, token, body)
                     in self._unacked.items()],
            seen=[(src, sorted(s)) for src, s in self._seen.items()],
            ack_outbox=[f.copy() for f in self._ack_outbox],
            give_ups=self.give_ups)

    def restore(self, snap: dict) -> None:
        self._next_seq = dict(snap["next_seq"])
        self._unacked = {
            (dst, seq): [frame.copy(), last, retries,
                         None if token is None else tuple(token),
                         Ctl(*body)]
            for dst, seq, frame, last, retries, token, body
            in snap["unacked"]}
        self._seen = {src: set(s) for src, s in snap["seen"]}
        self._ack_outbox = [f.copy() for f in snap["ack_outbox"]]
        self.give_ups = snap["give_ups"]
