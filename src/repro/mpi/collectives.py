"""Collectives composed from point-to-point (MPI Chapter 5 over the fabric).

Every algorithm here is a *reactive plan*: each rank posts its first
operation, and completion callbacks post the follow-on sends — the natural
shape for a tick-driven fabric, and exactly how tree collectives overlap
under loss (a subtree whose link is clean makes progress while another
subtree retransmits).

  bcast      binomial tree (log₂ n rounds)
  reduce     binomial tree combine toward the root
  allreduce  reduce + bcast
  alltoall   pairwise exchange, source-matched
  alltoallv  pairwise exchange with per-pair block sizes
  barrier    zero-byte allreduce

Buffers are numpy arrays (any dtype, C-contiguous); messages travel as raw
bytes, so reduce's ``op`` runs on the typed views.  Collectives reserve
tags at/above ``COLL_TAG_BASE`` — keep user tags below it.
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import numpy as np

from repro.mpi.communicator import Communicator

COLL_TAG_BASE = 1 << 20
TAG_BCAST = COLL_TAG_BASE + 0
TAG_REDUCE = COLL_TAG_BASE + 1
TAG_A2A = COLL_TAG_BASE + 2


def _vrank(r: int, root: int, n: int) -> int:
    return (r - root) % n


def _prank(v: int, root: int, n: int) -> int:
    return (v + root) % n


def _children(v: int, n: int) -> List[int]:
    """Binomial-tree children of virtual rank ``v``."""
    m = 1 if v == 0 else 1 << v.bit_length()
    out = []
    while v + m < n:
        out.append(v + m)
        m <<= 1
    return out


def _parent(v: int) -> int:
    return v - (1 << (v.bit_length() - 1))


def bcast(comm: Communicator, bufs: Sequence[np.ndarray], root: int = 0,
          max_ticks: int = 200_000) -> None:
    """Broadcast ``bufs[root]`` into every rank's ``bufs[r]`` (in place)."""
    n = comm.n_ranks
    if n == 1:
        return
    pending: List = []

    def fanout(r: int) -> None:
        v = _vrank(r, root, n)
        for c in _children(v, n):
            pending.append(comm.isend(r, _prank(c, root, n), bufs[r],
                                      tag=TAG_BCAST))

    for r in range(n):
        v = _vrank(r, root, n)
        if v == 0:
            fanout(r)
        else:
            req = comm.irecv(r, bufs[r],
                             source=_prank(_parent(v), root, n),
                             tag=TAG_BCAST)
            req.add_done_callback(lambda _q, r=r: fanout(r))
            pending.append(req)
    comm.wait_list(pending, max_ticks=max_ticks)


def reduce(comm: Communicator, sendbufs: Sequence[np.ndarray],
           root: int = 0, op: Callable = np.add,
           max_ticks: int = 200_000) -> np.ndarray:
    """Combine every rank's array with ``op`` toward ``root``; returns the
    reduced array (meaningful at the root, like MPI_Reduce)."""
    n = comm.n_ranks
    accs = [np.ascontiguousarray(b).copy() for b in sendbufs]
    if n == 1:
        return accs[root]
    tmps = [np.empty_like(a) for a in accs]
    pending: List = []

    def step(r: int, mask: int) -> None:
        v = _vrank(r, root, n)
        while mask < n:
            if v & mask:
                peer = _prank(v - mask, root, n)
                pending.append(comm.isend(r, peer, accs[r],
                                          tag=TAG_REDUCE))
                return
            if v + mask < n:
                peer = _prank(v + mask, root, n)
                req = comm.irecv(r, tmps[r], source=peer, tag=TAG_REDUCE)

                def combine(_q, r=r, mask=mask):
                    accs[r][...] = op(accs[r], tmps[r])
                    step(r, mask << 1)

                req.add_done_callback(combine)
                pending.append(req)
                return
            mask <<= 1

    for r in range(n):
        step(r, 1)
    comm.wait_list(pending, max_ticks=max_ticks)
    return accs[root]


def allreduce(comm: Communicator, sendbufs: Sequence[np.ndarray],
              op: Callable = np.add,
              max_ticks: int = 200_000) -> List[np.ndarray]:
    """reduce-to-0 + bcast; returns the per-rank result arrays."""
    res = reduce(comm, sendbufs, root=0, op=op, max_ticks=max_ticks)
    outs = [np.empty_like(res) for _ in range(comm.n_ranks)]
    outs[0][...] = res
    bcast(comm, outs, root=0, max_ticks=max_ticks)
    return outs


def alltoall(comm: Communicator, sends: Sequence[np.ndarray],
             max_ticks: int = 200_000) -> List[np.ndarray]:
    """``sends[r][j]`` goes to rank ``j``; returns ``recvs`` with
    ``recvs[r][i] == sends[i][r]`` (personalized exchange)."""
    n = comm.n_ranks
    recvs = [np.empty_like(np.ascontiguousarray(s)) for s in sends]
    pending: List = []
    for r in range(n):
        s = np.ascontiguousarray(sends[r])
        assert s.shape[0] == n, "alltoall sends need one block per rank"
        for j in range(n):
            pending.append(comm.irecv(r, recvs[r][j], source=j,
                                      tag=TAG_A2A))
            pending.append(comm.isend(r, j, s[j], tag=TAG_A2A))
    comm.wait_list(pending, max_ticks=max_ticks)
    return recvs


def alltoallv(comm: Communicator,
              blocks: Sequence[Sequence[np.ndarray]],
              max_ticks: int = 200_000) -> List[List[np.ndarray]]:
    """Variable-size exchange: ``blocks[r][j]`` goes from rank r to rank j;
    returns ``recvs[r][i]`` = block received at r from i (zero-size blocks
    allowed)."""
    n = comm.n_ranks
    recvs = [[np.empty_like(np.ascontiguousarray(blocks[i][r]))
              for i in range(n)] for r in range(n)]
    pending: List = []
    for r in range(n):
        for j in range(n):
            pending.append(comm.irecv(r, recvs[r][j], source=j,
                                      tag=TAG_A2A))
            pending.append(comm.isend(r, j,
                                      np.ascontiguousarray(blocks[r][j]),
                                      tag=TAG_A2A))
    comm.wait_list(pending, max_ticks=max_ticks)
    return recvs


def barrier(comm: Communicator, max_ticks: int = 200_000) -> None:
    """No rank leaves before every rank arrived (zero-byte allreduce)."""
    allreduce(comm, [np.zeros(1, np.uint8) for _ in range(comm.n_ranks)],
              max_ticks=max_ticks)
