"""Collectives composed from point-to-point (MPI Chapter 5 over the fabric).

Every collective is a *plan*: a reactive, whole-communicator state machine
that posts point-to-point requests and advances from their completion
callbacks — the natural shape for a tick-driven fabric, and exactly how
tree collectives overlap under loss (a subtree whose link is clean makes
progress while another subtree retransmits).  The nonblocking entry
points (``ibcast`` / ``ireduce`` / ``iallreduce`` / ``ialltoall`` /
``ialltoallv`` / ``ibarrier``) register the plan with the communicator
and return a :class:`CollRequest` handle supporting ``test``/``wait`` and
mixing freely with p2p handles in ``waitall``; the blocking wrappers keep
their historical signatures by posting and waiting.

Plan state is plain data (numpy arrays, ints, buffer-pool ids — never a
closure), so an in-flight collective checkpoints with the fabric and
restores into a fresh object graph: completion callbacks are re-derived
from each live request's ``ctoken`` and the algorithm resumes where the
snapshot left it.

Algorithms (selected per message size when ``algorithm="auto"``):

  bcast       "binomial"   binomial tree (⌈log₂ n⌉ rounds)
              "pipelined"  binomial tree over fixed-size segments — every
                           relay forwards segment s the moment it lands,
                           so the tree streams (⌈log₂ n⌉ + S − 1 rounds)
  reduce      binomial tree combine toward the root
  allreduce   "rd"     recursive doubling, non-power-of-two ranks folded
                       in by a pre/post exchange — ⌈log₂ n⌉ rounds
              "tree"   binomial reduce + binomial bcast (fewer messages)
              "rab"    Rabenseifner: reduce-scatter (recursive halving)
                       + allgather (recursive doubling) — each rank moves
                       ~2·(n−1)/n vectors instead of ⌈log₂ n⌉, the
                       bandwidth-optimal schedule for large vectors
              "linear" gather + fan-out at the root (n−1 rounds; the
                       baseline the log-step algorithms are measured
                       against)
  alltoall(v) "bruck"  store-and-forward, ⌈log₂ n⌉ rounds of ⌈n/2⌉
                       coalesced blocks (message-count optimal)
              "pairwise"  direct exchange, n−1 messages per rank

**Large-message fast path.**  Any plan message larger than the eager
staging slot is transparently *segmented*: the payload travels as
committed contiguous chunks (``MpiConfig.coll_seg_bytes``) through the
credit-managed rendezvous path, where the NIC's DDT-unpack context
scatters each segment straight into the posted receive region — no
staging-slot cap, and the segments of concurrent collectives pipeline
against the receiver's slot credits.  Handles carry ``rounds`` /
``msgs_total`` / ``bytes_wire`` so benchmarks can attribute wins to the
schedule, not the wire.

Reduction ``op`` must be commutative (np.add / np.maximum / ...): the
log-step schedules combine partial results in rank-dependent order.
Buffers are numpy arrays (any dtype, C-contiguous); messages travel as
raw bytes, so ``op`` runs on the typed views.  Collectives reserve tags
at/above ``COLL_TAG_BASE`` — keep user tags below it.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.mpi.communicator import COLL_TAG_BASE, Communicator
from repro.mpi.engine import Request

# ---- algorithm selection thresholds (bytes) ----
# Recursive doubling sends the full vector every round; past this size the
# lower-message-count tree wins.  Past ALLREDUCE_RAB_MIN_BYTES the
# bandwidth term dominates and Rabenseifner's reduce-scatter+allgather
# (2·(n−1)/n vectors on the wire per rank) beats both.  Bruck coalesces
# ~n/2 blocks per send, so it pays only while blocks are small
# (latency-bound regime).  Long broadcasts switch to the pipelined
# segment tree at BCAST_PIPELINE_MIN_BYTES.
ALLREDUCE_RD_MAX_BYTES = 32 * 1024
ALLREDUCE_RAB_MIN_BYTES = 64 * 1024
BCAST_PIPELINE_MIN_BYTES = 64 * 1024
ALLTOALL_BRUCK_MAX_BLOCK = 4 * 1024

# Reduction ops a checkpoint can name (plain-data snapshots store the
# name, not the callable).
OPS: Dict[str, Callable] = {
    "add": np.add, "max": np.maximum, "min": np.minimum,
    "prod": np.multiply,
}


def _op_name(op: Callable) -> Optional[str]:
    for k, v in OPS.items():
        if op is v:
            return k
    return None


def _vrank(r: int, root: int, n: int) -> int:
    return (r - root) % n


def _prank(v: int, root: int, n: int) -> int:
    return (v + root) % n


def _children(v: int, n: int) -> List[int]:
    """Binomial-tree children of virtual rank ``v``."""
    m = 1 if v == 0 else 1 << v.bit_length()
    out = []
    while v + m < n:
        out.append(v + m)
        m <<= 1
    return out


def _parent(v: int) -> int:
    return v - (1 << (v.bit_length() - 1))


def _log2floor(n: int) -> int:
    return n.bit_length() - 1


# rank <-> power-of-two participant mapping for the non-power-of-two fold
# (MPICH scheme: the first 2·rem ranks collapse pairwise into rem
# participants; even ranks sit out after handing their vector to the odd
# neighbour and take the result back in a post phase)
def _fold_newrank(r: int, rem: int) -> int:
    if r < 2 * rem:
        return -1 if r % 2 == 0 else r // 2
    return r - rem


def _fold_realrank(nr: int, rem: int) -> int:
    return 2 * nr + 1 if nr < rem else nr + rem


def _rab_schedule(nr: int, pof2: int, nelems: int) -> List[tuple]:
    """Rabenseifner round schedule for participant ``nr``: reduce-scatter
    by recursive halving, then allgather by recursive doubling in reverse.
    Each entry is ``(phase, partner_nr, (send_lo, send_hi),
    (recv_lo, recv_hi))`` in element offsets; partners always derive the
    same split point (it depends only on the shared higher address bits),
    so the ranges pair up exactly.  Ranges may be empty for tiny vectors."""
    rounds: List[tuple] = []
    hist: List[tuple] = []
    lo, hi = 0, nelems
    mask = pof2 >> 1
    while mask >= 1:
        pn = nr ^ mask
        mid = lo + (hi - lo) // 2
        if nr & mask:
            snd, rcv = (lo, mid), (mid, hi)
            lo = mid
        else:
            snd, rcv = (mid, hi), (lo, mid)
            hi = mid
        rounds.append(("rs", pn, snd, rcv))
        hist.append((pn, snd, rcv))
        mask >>= 1
    # allgather walks the halving tree back up: send what this rank now
    # owns fully reduced (the kept range), receive what it gave away
    for pn, snd, rcv in reversed(hist):
        rounds.append(("ag", pn, rcv, snd))
    return rounds


class CollRequest(Request):
    """Handle for a nonblocking collective: a :class:`Request` whose
    completion is the whole plan's; ``result`` carries the collective's
    return value (allreduce outputs, alltoall receive blocks, ...)."""

    def __init__(self, algorithm: str):
        super().__init__("coll")
        self.algorithm = algorithm
        self.result = None
        self.rounds = 0              # sequential communication rounds
        self.msgs_total = 0          # point-to-point messages posted
        self.bytes_wire = 0          # payload bytes put on the wire
        #                              (incl. segment padding — what the
        #                              fabric actually carries)


# --------------------------------------------------------------- plan base
class Plan:
    """A whole-communicator collective as a reactive state machine.

    Subclasses implement ``start`` (post the first wave of requests) and
    ``on_step`` (advance a rank when one of its requests completes), keep
    *all* algorithm state serializable, and produce ``result()`` when the
    last request drains.  Request↔plan linkage is the serializable step
    key: ``req.ctoken == (plan_id, key)``.
    """

    NAME = "plan"

    def __init__(self, comm: Communicator, pid: int, tag_base: int,
                 algorithm: Optional[str] = None):
        self.comm = comm
        self.pid = pid
        self.tag_base = tag_base
        self.pending = set()
        self.finished = False
        self._depth = 0        # posting re-entrancy depth (self-sends can
        #                        complete synchronously mid-start/on_step)
        self.owned_bids: List[int] = []
        # segmented-transport bookkeeping: base step key -> segments left,
        # and per-segment receive key -> (target bid, scratch bid, byte
        # offset, byte length) — all plain data, checkpoints with the plan
        self._seg_left: Dict[tuple, int] = {}
        self._seg_recv: Dict[tuple, tuple] = {}
        self.request = CollRequest(algorithm or self.NAME)
        self.request._comm = comm

    # ---- lifecycle -------------------------------------------------------
    def start(self) -> None:
        raise NotImplementedError

    def on_step(self, key: tuple, req: Request) -> None:
        pass

    def on_drain(self) -> None:
        self._finish()

    def result(self):
        return None

    # ---- posting helpers -------------------------------------------------
    def _adopt(self, arr: np.ndarray) -> int:
        bid = self.comm.pool.adopt(arr)
        self.owned_bids.append(bid)
        return bid

    def _buf(self, bid: int) -> np.ndarray:
        return self.comm.pool.get(bid)

    def _segmented(self, nbytes: int, a: int, b: int) -> bool:
        """Sender and receiver must agree: a plan message is segmented iff
        it exceeds the eager staging slot, the chunk datatype exists, and
        the endpoints differ (self-delivery never touches a slot)."""
        return (a != b and self.comm.seg_dtype is not None
                and nbytes > self.comm.cfg.eager_slot_bytes)

    def _send(self, src: int, dest: int, data: np.ndarray, key: tuple,
              round_: int = 0) -> None:
        data = np.ascontiguousarray(data)
        nbytes = int(data.nbytes)
        if not self._segmented(nbytes, src, dest):
            if src != dest:
                _check_eager_fit(self.comm, nbytes, "collective message")
                self.request.bytes_wire += nbytes
            req = self.comm.isend(src, dest, data,
                                  tag=self.tag_base + round_)
            self._track(req, key)
            return
        # large-message fast path: committed contiguous chunks through the
        # credit-managed rendezvous, NIC-unpacked into the posted region
        seg = self.comm.cfg.coll_seg_bytes
        u8 = data.reshape(-1).view(np.uint8)
        nseg = -(-nbytes // seg)
        self._seg_left[key] = nseg
        for i in range(nseg):
            ln = min(seg, nbytes - i * seg)
            chunk = np.zeros(seg, np.uint8)
            chunk[:ln] = u8[i * seg:i * seg + ln]
            req = self.comm.isend(src, dest, chunk,
                                  tag=self.tag_base + round_,
                                  datatype=self.comm.seg_dtype)
            self._track(req, ("sg",) + key + (i,))
            self.request.bytes_wire += seg

    def _recv(self, rank: int, bid: int, source: int, key: tuple,
              round_: int = 0) -> None:
        buf = self._buf(bid)
        nbytes = int(buf.nbytes)
        if not self._segmented(nbytes, rank, source):
            req = self.comm.irecv(rank, buf, source=source,
                                  tag=self.tag_base + round_, buf_id=bid)
            self._track(req, key)
            return
        seg = self.comm.cfg.coll_seg_bytes
        nseg = -(-nbytes // seg)
        self._seg_left[key] = nseg
        for i in range(nseg):
            ln = min(seg, nbytes - i * seg)
            sbid = self._adopt(np.zeros(seg, np.uint8))
            skey = ("rg",) + key + (i,)
            self._seg_recv[skey] = (bid, sbid, i * seg, ln)
            req = self.comm.irecv(rank, self._buf(sbid), source=source,
                                  tag=self.tag_base + round_, buf_id=sbid)
            self._track(req, skey)

    def _track(self, req: Request, key: tuple) -> None:
        assert key not in self.pending, f"duplicate plan step {key}"
        self.pending.add(key)
        self.request.msgs_total += 1
        req.ctoken = (self.pid, key)
        req.add_done_callback(lambda q, key=key: self._step(key, q))

    # ---- completion plumbing --------------------------------------------
    def _step(self, key: tuple, req: Request) -> None:
        if self.finished:
            return
        self.pending.discard(key)
        if req.error:
            self._abort(req.error)
            return
        deliver = True
        if key[0] in ("sg", "rg"):
            key = self._seg_step(key)
            deliver = key is not None
        if deliver:
            self._depth += 1
            try:
                self.on_step(key, req)
            finally:
                self._depth -= 1
        # drain only at depth 0: a synchronously-completing self-send must
        # not finish the plan while an outer start()/on_step() is still
        # posting the rest of its wave
        if not self.pending and not self.finished and self._depth == 0:
            self.on_drain()

    def _seg_step(self, key: tuple) -> Optional[tuple]:
        """One segment of a segmented plan message completed: land receive
        chunks in the target buffer; when the last segment of the base
        step drains, return the base key for on_step dispatch."""
        base = tuple(key[1:-1])
        if key[0] == "rg":
            tbid, sbid, off, ln = self._seg_recv.pop(key)
            tview = self._buf(tbid).reshape(-1).view(np.uint8)
            tview[off:off + ln] = self._buf(sbid)[:ln]
            self.comm.pool.release(sbid)
        left = self._seg_left[base] - 1
        if left:
            self._seg_left[base] = left
            return None
        del self._seg_left[base]
        return base

    def _abort(self, err: str) -> None:
        self.finished = True
        for bid in self.owned_bids:
            self.comm.pool.release(bid)
        self.comm._unregister_plan(self.pid)
        self.request._complete(error=err)

    def _finish(self) -> None:
        self.finished = True
        self.request.result = self.result()
        for bid in self.owned_bids:
            self.comm.pool.release(bid)
        self.comm._unregister_plan(self.pid)
        self.request._complete()

    # ---- checkpoint ------------------------------------------------------
    def snapshot(self) -> dict:
        return dict(name=self.NAME, tag_base=self.tag_base,
                    algorithm=self.request.algorithm,
                    rounds=self.request.rounds,
                    msgs_total=self.request.msgs_total,
                    bytes_wire=self.request.bytes_wire,
                    pending=sorted(self.pending),
                    owned_bids=list(self.owned_bids),
                    seg_left=sorted(self._seg_left.items()),
                    seg_recv=sorted(self._seg_recv.items()),
                    state=self._snap_state())

    @classmethod
    def from_snapshot(cls, comm: Communicator, pid: int,
                      snap: dict) -> "Plan":
        plan = cls.__new__(cls)
        Plan.__init__(plan, comm, pid, snap["tag_base"],
                      algorithm=snap["algorithm"])
        plan.request.rounds = snap["rounds"]
        plan.request.msgs_total = snap["msgs_total"]
        plan.request.bytes_wire = snap["bytes_wire"]
        plan.pending = set(tuple(k) for k in snap["pending"])
        plan.owned_bids = list(snap["owned_bids"])
        plan._seg_left = {tuple(k): v for k, v in snap["seg_left"]}
        plan._seg_recv = {tuple(k): tuple(v)
                          for k, v in snap["seg_recv"]}
        plan._restore_state(snap["state"])
        return plan

    def _snap_state(self) -> dict:
        return {}

    def _restore_state(self, state: dict) -> None:
        pass


# ------------------------------------------------------------------- bcast
class BcastPlan(Plan):
    """Binomial-tree broadcast of ``bufs[root]`` into every ``bufs[r]``."""

    NAME = "bcast"

    def __init__(self, comm, pid, tag_base, bufs: Sequence[np.ndarray],
                 root: int = 0):
        super().__init__(comm, pid, tag_base)
        self.n = comm.n_ranks
        self.root = root
        self.bids = [self._adopt(np.ascontiguousarray(b)) for b in bufs]
        self.request.rounds = max(1, self.n - 1).bit_length()

    def start(self) -> None:
        for r in range(self.n):
            v = _vrank(r, self.root, self.n)
            if v == 0:
                self._fanout(r)
            else:
                parent = _prank(_parent(v), self.root, self.n)
                self._recv(r, self.bids[r], source=parent, key=("br", r, 0))

    def _fanout(self, r: int) -> None:
        v = _vrank(r, self.root, self.n)
        for c in _children(v, self.n):
            self._send(r, _prank(c, self.root, self.n), self._buf(self.bids[r]),
                       key=("bs", r, c))

    def on_step(self, key, req) -> None:
        if key[0] == "br":
            self._fanout(key[1])

    def result(self):
        return [self._buf(b) for b in self.bids]

    def _snap_state(self):
        return dict(n=self.n, root=self.root, bids=list(self.bids))

    def _restore_state(self, s):
        self.n, self.root, self.bids = s["n"], s["root"], list(s["bids"])


class BcastPipelinedPlan(Plan):
    """Pipelined-segment binomial-tree broadcast for long messages: the
    payload is cut into ``MpiConfig.coll_seg_bytes`` segments, each relay
    forwards segment ``s`` to its children the moment it lands (distinct
    tag per segment, so segments overtake freely), and every segment
    travels as one committed chunk over the credit-managed rendezvous —
    the tree streams instead of storing-and-forwarding the whole vector:
    ⌈log₂ n⌉ + S − 1 pipeline rounds instead of ⌈log₂ n⌉ · S.
    """

    NAME = "bcast_pipelined"

    def __init__(self, comm, pid, tag_base, bufs: Sequence[np.ndarray],
                 root: int = 0):
        super().__init__(comm, pid, tag_base)
        assert comm.seg_dtype is not None, (
            "pipelined bcast needs the collective segment datatype "
            "(MpiConfig.coll_seg_bytes > 0, unfrozen registry)")
        self.n = comm.n_ranks
        self.root = root
        self.bids = [self._adopt(np.ascontiguousarray(b)) for b in bufs]
        self.nbytes = int(self._buf(self.bids[root]).nbytes)
        self.seg = comm.cfg.coll_seg_bytes
        self.nseg = max(1, -(-self.nbytes // self.seg))
        from repro.mpi.communicator import _PLAN_TAG_SPAN
        assert self.nseg <= _PLAN_TAG_SPAN, (
            f"{self.nseg} segments exceed the plan tag block "
            f"({_PLAN_TAG_SPAN}) — raise MpiConfig.coll_seg_bytes")
        self.scratch: Dict[tuple, int] = {}      # (rank, seg) -> bid
        self.request.rounds = max(1, self.n - 1).bit_length() \
            + self.nseg - 1

    def _seg_span(self, s: int) -> Tuple[int, int]:
        off = s * self.seg
        return off, min(self.seg, self.nbytes - off)

    def start(self) -> None:
        for r in range(self.n):
            v = _vrank(r, self.root, self.n)
            if v == 0:
                for s in range(self.nseg):
                    self._fan_seg(r, s)
            else:
                parent = _prank(_parent(v), self.root, self.n)
                for s in range(self.nseg):
                    sbid = self._adopt(np.zeros(self.seg, np.uint8))
                    self.scratch[(r, s)] = sbid
                    req = self.comm.irecv(r, self._buf(sbid),
                                          source=parent,
                                          tag=self.tag_base + s,
                                          buf_id=sbid)
                    self._track(req, ("pr", r, s))

    def _fan_seg(self, r: int, s: int) -> None:
        v = _vrank(r, self.root, self.n)
        children = _children(v, self.n)
        if not children:
            return
        off, ln = self._seg_span(s)
        u8 = self._buf(self.bids[r]).reshape(-1).view(np.uint8)
        chunk = np.zeros(self.seg, np.uint8)
        chunk[:ln] = u8[off:off + ln]
        for c in children:
            req = self.comm.isend(r, _prank(c, self.root, self.n), chunk,
                                  tag=self.tag_base + s,
                                  datatype=self.comm.seg_dtype)
            self._track(req, ("ps", r, c, s))
            self.request.bytes_wire += self.seg

    def on_step(self, key, req) -> None:
        if key[0] != "pr":
            return
        _, r, s = key
        sbid = self.scratch.pop((r, s))
        off, ln = self._seg_span(s)
        u8 = self._buf(self.bids[r]).reshape(-1).view(np.uint8)
        u8[off:off + ln] = self._buf(sbid)[:ln]
        self.comm.pool.release(sbid)
        self._fan_seg(r, s)

    def result(self):
        return [self._buf(b) for b in self.bids]

    def _snap_state(self):
        return dict(n=self.n, root=self.root, bids=list(self.bids),
                    nbytes=self.nbytes, seg=self.seg, nseg=self.nseg,
                    scratch=sorted(self.scratch.items()))

    def _restore_state(self, s):
        self.n, self.root = s["n"], s["root"]
        self.bids = list(s["bids"])
        self.nbytes, self.seg, self.nseg = s["nbytes"], s["seg"], s["nseg"]
        self.scratch = {tuple(k): v for k, v in s["scratch"]}


def _check_eager_fit(comm: Communicator, nbytes: int, what: str) -> None:
    """Only reachable when segmentation is unavailable (a frozen registry
    without the chunk type, or ``coll_seg_bytes=0``): unsegmented plan
    messages ship raw bytes through the eager path and must fit a staging
    slot — fail at post time with an actionable message."""
    assert nbytes <= comm.cfg.eager_slot_bytes, (
        f"{what} of {nbytes}B exceeds the {comm.cfg.eager_slot_bytes}B "
        f"eager staging slot and the communicator has no collective "
        f"segment datatype (frozen registry without '__coll_seg__', or "
        f"MpiConfig.coll_seg_bytes=0) — enable segmentation or raise "
        f"eager_slot_bytes")


# ------------------------------------------------------- binomial reduce
class _ReduceState:
    """Shared acc/tmp/op state for the reduction plans: buffer adoption at
    construction and named-op (de)serialization for checkpoints."""

    def _init_reduce_state(self, sendbufs, op) -> None:
        self._op = op
        self.op_name = _op_name(op)
        accs = [np.ascontiguousarray(b).copy() for b in sendbufs]
        self.acc_bids = [self._adopt(a) for a in accs]
        self.tmp_bids = [self._adopt(np.empty_like(a)) for a in accs]

    def _snap_reduce_state(self) -> dict:
        assert self.op_name is not None, (
            "cannot checkpoint a collective with an unregistered reduction "
            "op — use one of repro.mpi.collectives.OPS or register yours")
        return dict(n=self.n, op=self.op_name, acc=list(self.acc_bids),
                    tmp=list(self.tmp_bids))

    def _restore_reduce_state(self, s: dict) -> None:
        self.n = s["n"]
        self.op_name = s["op"]
        self._op = OPS[s["op"]]
        self.acc_bids, self.tmp_bids = list(s["acc"]), list(s["tmp"])


class _TreeReduce:
    """Shared binomial-combine logic (used by ReducePlan and the tree
    allreduce).  Host class must provide masks/acc_bids/tmp_bids/_op and
    the plan posting helpers."""

    def _tree_kick(self, r: int, round_: int = 0) -> None:
        n, root = self.n, self.root
        v = _vrank(r, root, n)
        mask = self.masks[r]
        while mask < n:
            if v & mask:
                peer = _prank(v - mask, root, n)
                self.masks[r] = n            # this rank's combine is done
                self._send(r, peer, self._buf(self.acc_bids[r]),
                           key=("rs", r, mask), round_=round_)
                return
            if v + mask < n:
                peer = _prank(v + mask, root, n)
                self.masks[r] = mask
                self._recv(r, self.tmp_bids[r], source=peer,
                           key=("rr", r, mask), round_=round_)
                return
            mask <<= 1
            self.masks[r] = mask

    def _tree_combine(self, key, round_: int = 0) -> None:
        _, r, mask = key
        acc, tmp = self._buf(self.acc_bids[r]), self._buf(self.tmp_bids[r])
        acc[...] = self._op(acc, tmp)
        self.masks[r] = mask << 1
        self._tree_kick(r, round_=round_)


class ReducePlan(Plan, _ReduceState, _TreeReduce):
    """Binomial-tree reduce toward ``root``; result() is the root's
    combined array (like MPI_Reduce, only meaningful there)."""

    NAME = "reduce"

    def __init__(self, comm, pid, tag_base, sendbufs, root=0, op=np.add):
        super().__init__(comm, pid, tag_base)
        self.n = comm.n_ranks
        self.root = root
        self._init_reduce_state(sendbufs, op)
        self.masks = [1] * self.n
        self.request.rounds = max(1, self.n - 1).bit_length()

    def start(self) -> None:
        for r in range(self.n):
            self._tree_kick(r)

    def on_step(self, key, req) -> None:
        if key[0] == "rr":
            self._tree_combine(key)

    def result(self):
        return self._buf(self.acc_bids[self.root])

    def _snap_state(self):
        return dict(self._snap_reduce_state(), root=self.root,
                    masks=list(self.masks))

    def _restore_state(self, s):
        self._restore_reduce_state(s)
        self.root = s["root"]
        self.masks = list(s["masks"])


# --------------------------------------------------------------- allreduce
class AllreduceTreePlan(ReducePlan):
    """reduce-to-0 then binomial bcast of the result (the low-message-count
    algorithm for large vectors: ≤ 2·⌈log₂ n⌉ rounds, 2(n−1) messages)."""

    NAME = "allreduce_tree"

    def __init__(self, comm, pid, tag_base, sendbufs, op=np.add):
        super().__init__(comm, pid, tag_base, sendbufs, root=0, op=op)
        self.phase = "reduce"
        self.request.rounds = 2 * max(1, self.n - 1).bit_length()

    def on_drain(self) -> None:
        if self.phase == "reduce":
            self.phase = "bcast"
            for r in range(self.n):
                if r == 0:
                    self._bcast_fanout(r)
                else:
                    v = _vrank(r, 0, self.n)
                    self._recv(r, self.acc_bids[r],
                               source=_prank(_parent(v), 0, self.n),
                               key=("br", r, 0), round_=1)
            if not self.pending:
                self._finish()
        else:
            self._finish()

    def _bcast_fanout(self, r: int) -> None:
        v = _vrank(r, 0, self.n)
        for c in _children(v, self.n):
            self._send(r, _prank(c, 0, self.n),
                       self._buf(self.acc_bids[r]),
                       key=("bs", r, c), round_=1)

    def on_step(self, key, req) -> None:
        if key[0] == "rr":
            self._tree_combine(key)
        elif key[0] == "br":
            self._bcast_fanout(key[1])

    def result(self):
        return [self._buf(b) for b in self.acc_bids]

    def _snap_state(self):
        s = super()._snap_state()
        s["phase"] = self.phase
        return s

    def _restore_state(self, s):
        super()._restore_state(s)
        self.phase = s["phase"]


class AllreduceRDPlan(Plan, _ReduceState):
    """Recursive-doubling allreduce — the latency-optimal ⌈log₂ n⌉-round
    schedule (MPICH's short-vector algorithm).  Non-power-of-two rank
    counts fold the first ``2·rem`` ranks pairwise into ``pof2``
    participants, run the doubling, and fan the result back out."""

    NAME = "allreduce_rd"

    def __init__(self, comm, pid, tag_base, sendbufs, op=np.add):
        super().__init__(comm, pid, tag_base)
        self.n = comm.n_ranks
        self._init_reduce_state(sendbufs, op)
        self.pof2 = 1 << _log2floor(self.n)
        self.rem = self.n - self.pof2
        self.nrounds = _log2floor(self.pof2)
        self.request.rounds = self.nrounds + (2 if self.rem else 0)

    # rank <-> recursive-doubling participant mapping (MPICH scheme)
    def _newrank(self, r: int) -> int:
        return _fold_newrank(r, self.rem)

    def _realrank(self, nr: int) -> int:
        return _fold_realrank(nr, self.rem)

    def start(self) -> None:
        post_round = 1 + self.nrounds
        for r in range(self.n):
            if self.rem and r < 2 * self.rem:
                if r % 2 == 0:
                    # fold into the odd neighbour; take the result back in
                    # the post phase (recv posted now, tag-disambiguated)
                    self._send(r, r + 1, self._buf(self.acc_bids[r]),
                               key=("pres", r, 0), round_=0)
                    self._recv(r, self.acc_bids[r], source=r + 1,
                               key=("postr", r, 0), round_=post_round)
                else:
                    self._recv(r, self.tmp_bids[r], source=r - 1,
                               key=("prer", r, 0), round_=0)
            else:
                self._rd_round(r, 0)

    def _rd_round(self, r: int, ki: int) -> None:
        if ki >= self.nrounds:
            if self.rem and r < 2 * self.rem:
                # odd fold-rank hands the result back to its even partner
                self._send(r, r - 1, self._buf(self.acc_bids[r]),
                           key=("posts", r, 0), round_=1 + self.nrounds)
            return
        nr = self._newrank(r)
        partner = self._realrank(nr ^ (1 << ki))
        self._send(r, partner, self._buf(self.acc_bids[r]),
                   key=("rds", r, ki), round_=1 + ki)
        self._recv(r, self.tmp_bids[r], source=partner,
                   key=("rdr", r, ki), round_=1 + ki)

    def on_step(self, key, req) -> None:
        kind, r = key[0], key[1]
        if kind == "prer":
            self._combine(r)
            self._rd_round(r, 0)
        elif kind == "rdr":
            self._combine(r)
            self._rd_round(r, key[2] + 1)

    def _combine(self, r: int) -> None:
        acc, tmp = self._buf(self.acc_bids[r]), self._buf(self.tmp_bids[r])
        acc[...] = self._op(acc, tmp)

    def result(self):
        return [self._buf(b) for b in self.acc_bids]

    def _snap_state(self):
        return self._snap_reduce_state()

    def _restore_state(self, s):
        self._restore_reduce_state(s)
        self.pof2 = 1 << _log2floor(self.n)
        self.rem = self.n - self.pof2
        self.nrounds = _log2floor(self.pof2)


class AllreduceRabenseifnerPlan(Plan, _ReduceState):
    """Rabenseifner's allreduce: reduce-scatter by recursive halving, then
    allgather by recursive doubling — ⌈log₂ n⌉ + ⌈log₂ n⌉ rounds moving
    only ~2·(n−1)/n of the vector per rank, the bandwidth-optimal schedule
    for the large reductions that dominate a data-parallel training step.
    Non-power-of-two rank counts fold the first ``2·rem`` ranks pairwise
    into ``pof2`` participants (full-vector pre/post exchange, as in the
    recursive-doubling plan).  Every half-vector message above the eager
    slot rides the segmented rendezvous fast path.
    """

    NAME = "allreduce_rab"

    def __init__(self, comm, pid, tag_base, sendbufs, op=np.add):
        super().__init__(comm, pid, tag_base)
        self.n = comm.n_ranks
        self._init_reduce_state(sendbufs, op)
        self.nelems = int(self._buf(self.acc_bids[0]).size)
        self._derive()
        self.ridx = [0] * self.n      # per-rank position in its schedule
        self.scratch = [-1] * self.n  # per-rank in-flight recv buffer
        self.request.rounds = 2 * self.nrounds + (2 if self.rem else 0)

    def _derive(self) -> None:
        self.pof2 = 1 << _log2floor(self.n)
        self.rem = self.n - self.pof2
        self.nrounds = _log2floor(self.pof2)
        self.post_round = 1 + 2 * self.nrounds
        self._scheds: Dict[int, List[tuple]] = {}

    def _sched(self, r: int) -> List[tuple]:
        s = self._scheds.get(r)
        if s is None:
            nr = _fold_newrank(r, self.rem)
            assert nr >= 0
            s = self._scheds[r] = _rab_schedule(nr, self.pof2, self.nelems)
        return s

    def start(self) -> None:
        for r in range(self.n):
            if self.rem and r < 2 * self.rem:
                if r % 2 == 0:
                    # fold into the odd neighbour; the final vector comes
                    # back in the post phase (recv posted now)
                    self._send(r, r + 1, self._buf(self.acc_bids[r]),
                               key=("fps", r), round_=0)
                    self._recv(r, self.acc_bids[r], source=r + 1,
                               key=("por", r), round_=self.post_round)
                else:
                    self._recv(r, self.tmp_bids[r], source=r - 1,
                               key=("fpr", r), round_=0)
            else:
                self._kick(r)

    def _kick(self, r: int) -> None:
        """Advance rank ``r`` through its schedule: post the round's send
        and receive; rounds whose receive range is empty (vectors shorter
        than pof2) complete immediately."""
        sched = self._sched(r)
        flat = self._buf(self.acc_bids[r]).reshape(-1)
        while self.ridx[r] < len(sched):
            k = self.ridx[r]
            _, pn, (slo, shi), (rlo, rhi) = sched[k]
            partner = _fold_realrank(pn, self.rem)
            if shi > slo:
                self._send(r, partner, flat[slo:shi], key=("ks", r, k),
                           round_=1 + k)
            if rhi > rlo:
                sbid = self._adopt(np.empty(rhi - rlo, flat.dtype))
                self.scratch[r] = sbid
                self._recv(r, sbid, source=partner, key=("kr", r, k),
                           round_=1 + k)
                return
            self.ridx[r] = k + 1
        if self.rem and r < 2 * self.rem:
            # odd fold rank hands the full result back to its even partner
            self._send(r, r - 1, self._buf(self.acc_bids[r]),
                       key=("pos", r), round_=self.post_round)

    def on_step(self, key, req) -> None:
        kind, r = key[0], key[1]
        if kind == "fpr":
            acc = self._buf(self.acc_bids[r])
            acc[...] = self._op(acc, self._buf(self.tmp_bids[r]))
            self._kick(r)
        elif kind == "kr":
            k = key[2]
            phase, _, _, (rlo, rhi) = self._sched(r)[k]
            flat = self._buf(self.acc_bids[r]).reshape(-1)
            data = self._buf(self.scratch[r])
            if phase == "rs":
                flat[rlo:rhi] = self._op(flat[rlo:rhi], data)
            else:
                flat[rlo:rhi] = data
            self.comm.pool.release(self.scratch[r])
            self.scratch[r] = -1
            self.ridx[r] = k + 1
            self._kick(r)

    def result(self):
        return [self._buf(b) for b in self.acc_bids]

    def _snap_state(self):
        return dict(self._snap_reduce_state(), nelems=self.nelems,
                    ridx=list(self.ridx), scratch=list(self.scratch))

    def _restore_state(self, s):
        self._restore_reduce_state(s)
        self.nelems = s["nelems"]
        self._derive()
        self.ridx = list(s["ridx"])
        self.scratch = list(s["scratch"])


class AllreduceLinearPlan(Plan, _ReduceState):
    """Naive gather + fan-out at rank 0 — n−1 sequentialized rounds at the
    root.  The baseline the log-step schedules are benchmarked against."""

    NAME = "allreduce_linear"

    def __init__(self, comm, pid, tag_base, sendbufs, op=np.add):
        super().__init__(comm, pid, tag_base)
        self.n = comm.n_ranks
        self._init_reduce_state(sendbufs, op)
        self.gathered = 0
        self.request.rounds = max(1, self.n - 1)

    def start(self) -> None:
        for i in range(1, self.n):
            self._send(i, 0, self._buf(self.acc_bids[i]),
                       key=("gs", i, 0), round_=0)
            self._recv(0, self.tmp_bids[i], source=i,
                       key=("gr", 0, i), round_=0)
            self._recv(i, self.acc_bids[i], source=0,
                       key=("br", i, 0), round_=1)

    def on_step(self, key, req) -> None:
        if key[0] != "gr":
            return
        acc = self._buf(self.acc_bids[0])
        acc[...] = self._op(acc, self._buf(self.tmp_bids[key[2]]))
        self.gathered += 1
        if self.gathered == self.n - 1:
            for i in range(1, self.n):
                self._send(0, i, acc, key=("bs", 0, i), round_=1)

    def result(self):
        return [self._buf(b) for b in self.acc_bids]

    def _snap_state(self):
        return dict(self._snap_reduce_state(), gathered=self.gathered)

    def _restore_state(self, s):
        self._restore_reduce_state(s)
        self.gathered = s["gathered"]


# ------------------------------------------------------------- alltoall(v)
def _blocks_meta(blocks):
    """(sizes, meta) matrices for an n×n block exchange: byte size and
    (dtype, shape) of every ``blocks[i][j]``."""
    n = len(blocks)
    sizes = [[int(np.ascontiguousarray(blocks[i][j]).nbytes)
              for j in range(n)] for i in range(n)]
    meta = [[(str(blocks[i][j].dtype), tuple(blocks[i][j].shape))
             for j in range(n)] for i in range(n)]
    return sizes, meta


def _block_u8(arr) -> np.ndarray:
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8).copy()


def _u8_as(arr_u8: np.ndarray, dtype: str, shape) -> np.ndarray:
    return arr_u8.view(np.dtype(dtype)).reshape(shape)


class _ExchangeResult:
    """Shared result assembly for the alltoall plans: ``final(r, i)`` must
    return the uint8 bytes rank ``r`` received from rank ``i``."""

    def result(self):
        n = self.n
        if self.mode == "a2av":
            return [[_u8_as(self.final(r, i), *self.meta[i][r])
                     for i in range(n)] for r in range(n)]
        outs = []
        for r in range(n):
            # container dtype/shape follow rank r's send array, matching
            # the historical np.empty_like(sends[r]) semantics
            dtype, shape = self.meta[r][0]
            out = np.empty((n,) + shape, np.dtype(dtype))
            for i in range(n):
                out[i] = _u8_as(self.final(r, i), dtype, shape)
            outs.append(out)
        return outs


class AlltoallPairwisePlan(_ExchangeResult, Plan):
    """Direct personalized exchange: every pair trades one message —
    n−1 sends per rank, one round, bandwidth-optimal for large blocks."""

    NAME = "alltoall_pairwise"

    def __init__(self, comm, pid, tag_base, blocks, mode="a2av"):
        super().__init__(comm, pid, tag_base)
        self.n = comm.n_ranks
        self.mode = mode
        self.sizes, self.meta = _blocks_meta(blocks)
        self.send_u8 = [[_block_u8(blocks[i][j]) for j in range(self.n)]
                        for i in range(self.n)]
        self.recv_bids = [[self._adopt(np.zeros(self.sizes[i][r], np.uint8))
                           for i in range(self.n)] for r in range(self.n)]
        self.request.rounds = max(1, self.n - 1)

    def start(self) -> None:
        for r in range(self.n):
            for j in range(self.n):
                self._recv(r, self.recv_bids[r][j], source=j,
                           key=("ar", r, j))
                self._send(r, j, self.send_u8[r][j], key=("as", r, j))

    def final(self, r: int, i: int) -> np.ndarray:
        return self._buf(self.recv_bids[r][i])

    def _snap_state(self):
        return dict(n=self.n, mode=self.mode, sizes=self.sizes,
                    meta=self.meta, recv=self.recv_bids,
                    send=[[b.copy() for b in row] for row in self.send_u8])

    def _restore_state(self, s):
        self.n, self.mode = s["n"], s["mode"]
        self.sizes = [list(row) for row in s["sizes"]]
        self.meta = [[(d, tuple(sh)) for d, sh in row] for row in s["meta"]]
        self.recv_bids = [list(row) for row in s["recv"]]
        self.send_u8 = [[b.copy() for b in row] for row in s["send"]]


class AlltoallBruckPlan(_ExchangeResult, Plan):
    """Bruck's store-and-forward alltoall: ⌈log₂ n⌉ rounds, each sending
    one coalesced message of the ⌈n/2⌉ blocks whose slot index has the
    round's bit set — the message-count-optimal schedule for small blocks
    (PsPIN's regime, where collective *message count* dominates).

    Slot invariant: after the local rotation ``slot[i] = block(r → r+i)``,
    a block needing to travel distance ``i`` rides exactly the rounds
    whose bit is set in ``i``, and always occupies slot ``i`` — so at the
    end, rank r's slot i holds the block *from* rank (r−i) mod n.  Slot
    sizes along the way follow from the same invariant, which is how the
    receiver of a coalesced message knows where to cut it.
    """

    NAME = "alltoall_bruck"

    def __init__(self, comm, pid, tag_base, blocks, mode="a2av"):
        super().__init__(comm, pid, tag_base)
        n = self.n = comm.n_ranks
        self.mode = mode
        self.sizes, self.meta = _blocks_meta(blocks)
        self.ks = [1 << i for i in range(max(1, n - 1).bit_length())
                   if (1 << i) < n]
        # local rotation: slot i of rank r starts as the block r → (r+i)%n
        self.slots = [[_block_u8(blocks[r][(r + i) % n]) for i in range(n)]
                      for r in range(n)]
        self.scratch = [-1] * n           # per-rank in-flight recv buffer
        self.request.rounds = max(1, len(self.ks))

    def _occupant(self, rank: int, i: int, pm: int):
        """(src, dst) of the block in ``rank``'s slot ``i`` after the
        rounds whose bits lie in ``pm`` have been processed."""
        src = (rank - (i & pm)) % self.n
        return src, (src + i) % self.n

    def start(self) -> None:
        if self.n == 1:
            return
        for r in range(self.n):
            self._post_round(r, 0)

    def _post_round(self, r: int, ki: int) -> None:
        n, k = self.n, self.ks[ki]
        pm = k - 1
        idxs = [i for i in range(1, n) if i & k]
        dest, src = (r + k) % n, (r - k) % n
        payload = np.concatenate([self.slots[r][i] for i in idxs]) \
            if idxs else np.zeros(0, np.uint8)
        self._send(r, dest, payload, key=("xs", r, ki), round_=ki)
        in_bytes = sum(self.sizes[s][d] for s, d in
                       (self._occupant(src, i, pm) for i in idxs))
        bid = self._adopt(np.zeros(in_bytes, np.uint8))
        self.scratch[r] = bid
        self._recv(r, bid, source=src, key=("xr", r, ki), round_=ki)

    def on_step(self, key, req) -> None:
        if key[0] != "xr":
            return
        _, r, ki = key
        n, k = self.n, self.ks[ki]
        pm = k - 1
        src = (r - k) % n
        data = self._buf(self.scratch[r])
        off = 0
        for i in (i for i in range(1, n) if i & k):
            s, d = self._occupant(src, i, pm)
            ln = self.sizes[s][d]
            self.slots[r][i] = data[off:off + ln].copy()
            off += ln
        self.comm.pool.release(self.scratch[r])
        self.scratch[r] = -1
        if ki + 1 < len(self.ks):
            self._post_round(r, ki + 1)

    def final(self, r: int, i: int) -> np.ndarray:
        return self.slots[r][(r - i) % self.n]

    def _snap_state(self):
        return dict(n=self.n, mode=self.mode, sizes=self.sizes,
                    meta=self.meta, scratch=list(self.scratch),
                    slots=[[b.copy() for b in row] for row in self.slots])

    def _restore_state(self, s):
        self.n, self.mode = s["n"], s["mode"]
        self.sizes = [list(row) for row in s["sizes"]]
        self.meta = [[(d, tuple(sh)) for d, sh in row] for row in s["meta"]]
        self.scratch = list(s["scratch"])
        self.slots = [[b.copy() for b in row] for row in s["slots"]]
        self.ks = [1 << i for i in range(max(1, self.n - 1).bit_length())
                   if (1 << i) < self.n]


PLAN_TYPES: Dict[str, type] = {
    p.NAME: p for p in (BcastPlan, BcastPipelinedPlan, ReducePlan,
                        AllreduceTreePlan, AllreduceRDPlan,
                        AllreduceRabenseifnerPlan, AllreduceLinearPlan,
                        AlltoallPairwisePlan, AlltoallBruckPlan)
}


# ----------------------------------------------------- nonblocking entries
def _start(comm: Communicator, cls, *args, **kw) -> CollRequest:
    pid, tag_base = comm._new_plan_slot()
    plan = cls(comm, pid, tag_base, *args, **kw)
    comm._register_plan(pid, plan)
    plan._depth += 1
    try:
        plan.start()
    finally:
        plan._depth -= 1
    if not plan.pending and not plan.finished:
        plan.on_drain()        # degenerate (n == 1) or all-local case
    return plan.request


def ibcast(comm: Communicator, bufs: Sequence[np.ndarray],
           root: int = 0, algorithm: str = "auto") -> CollRequest:
    """Nonblocking broadcast of ``bufs[root]`` into every ``bufs[r]``
    (in place); ``result`` is the buffer list.  ``algorithm``:
    "binomial", "pipelined" (segment-streaming tree for long messages),
    or "auto" by message size."""
    nbytes = int(np.ascontiguousarray(bufs[root]).nbytes)
    if algorithm == "auto":
        algorithm = "pipelined" if (nbytes >= BCAST_PIPELINE_MIN_BYTES
                                    and comm.seg_dtype is not None) \
            else "binomial"
    cls = {"binomial": BcastPlan,
           "pipelined": BcastPipelinedPlan}[algorithm]
    return _start(comm, cls, bufs, root)


def ireduce(comm: Communicator, sendbufs: Sequence[np.ndarray],
            root: int = 0, op: Callable = np.add) -> CollRequest:
    """Nonblocking reduce toward ``root``; ``result`` is the combined
    array (meaningful at the root, like MPI_Reduce)."""
    return _start(comm, ReducePlan, sendbufs, root, op)


def iallreduce(comm: Communicator, sendbufs: Sequence[np.ndarray],
               op: Callable = np.add,
               algorithm: str = "auto") -> CollRequest:
    """Nonblocking allreduce; ``result`` is the per-rank output list.
    ``algorithm``: "rd" (recursive doubling), "tree" (reduce+bcast),
    "rab" (Rabenseifner reduce-scatter+allgather, the large-vector
    bandwidth winner), "linear" (baseline), or "auto" by message size."""
    nbytes = int(np.ascontiguousarray(sendbufs[0]).nbytes)
    if algorithm == "auto":
        if nbytes <= ALLREDUCE_RD_MAX_BYTES:
            algorithm = "rd"
        elif nbytes < ALLREDUCE_RAB_MIN_BYTES or comm.seg_dtype is None:
            algorithm = "tree"
        else:
            algorithm = "rab"
    cls = {"rd": AllreduceRDPlan, "tree": AllreduceTreePlan,
           "rab": AllreduceRabenseifnerPlan,
           "linear": AllreduceLinearPlan}[algorithm]
    return _start(comm, cls, sendbufs, op)


def _a2a_blocks(sends: Sequence[np.ndarray], n: int):
    blocks = []
    for r in range(n):
        s = np.ascontiguousarray(sends[r])
        assert s.shape[0] == n, "alltoall sends need one block per rank"
        blocks.append([s[j] for j in range(n)])
    return blocks


def ialltoall(comm: Communicator, sends: Sequence[np.ndarray],
              algorithm: str = "auto") -> CollRequest:
    """Nonblocking personalized exchange (``result[r][i] == sends[i][r]``).
    ``algorithm``: "bruck", "pairwise", or "auto" by block size."""
    blocks = _a2a_blocks(sends, comm.n_ranks)
    cls = _pick_a2a(comm, blocks, algorithm)
    return _start(comm, cls, blocks, mode="a2a")


def ialltoallv(comm: Communicator,
               blocks: Sequence[Sequence[np.ndarray]],
               algorithm: str = "auto") -> CollRequest:
    """Nonblocking variable-size exchange; ``result[r][i]`` is the block
    received at r from i (zero-size blocks allowed)."""
    cls = _pick_a2a(comm, blocks, algorithm)
    return _start(comm, cls, blocks, mode="a2av")


def _pick_a2a(comm, blocks, algorithm: str):
    n = comm.n_ranks
    max_block = max((int(np.ascontiguousarray(b).nbytes)
                     for row in blocks for b in row), default=0)
    if algorithm == "auto":
        # Bruck coalesces ~n/2 blocks per message; keep the coalesced
        # payload inside the eager staging slot with room to spare
        coalesced = max_block * ((n + 1) // 2)
        algorithm = "bruck" if (max_block <= ALLTOALL_BRUCK_MAX_BLOCK
                                and coalesced <= comm.cfg.eager_slot_bytes
                                // 2) else "pairwise"
    return {"bruck": AlltoallBruckPlan,
            "pairwise": AlltoallPairwisePlan}[algorithm]


def ibarrier(comm: Communicator) -> CollRequest:
    """Nonblocking barrier: 1-byte recursive-doubling allreduce — no rank's
    handle completes before every rank has entered."""
    return iallreduce(comm, [np.zeros(1, np.uint8)
                             for _ in range(comm.n_ranks)], op=np.add,
                      algorithm="rd")


# ------------------------------------------------------- blocking wrappers
def bcast(comm: Communicator, bufs: Sequence[np.ndarray], root: int = 0,
          max_ticks: int = 200_000, algorithm: str = "auto") -> None:
    """Broadcast ``bufs[root]`` into every rank's ``bufs[r]`` (in place)."""
    comm.wait(ibcast(comm, bufs, root=root, algorithm=algorithm),
              max_ticks=max_ticks)


def reduce(comm: Communicator, sendbufs: Sequence[np.ndarray],
           root: int = 0, op: Callable = np.add,
           max_ticks: int = 200_000) -> np.ndarray:
    """Combine every rank's array with ``op`` toward ``root``; returns the
    reduced array (meaningful at the root, like MPI_Reduce)."""
    req = ireduce(comm, sendbufs, root=root, op=op)
    comm.wait(req, max_ticks=max_ticks)
    return req.result


def allreduce(comm: Communicator, sendbufs: Sequence[np.ndarray],
              op: Callable = np.add, max_ticks: int = 200_000,
              algorithm: str = "auto") -> List[np.ndarray]:
    """Allreduce; returns the per-rank result arrays."""
    req = iallreduce(comm, sendbufs, op=op, algorithm=algorithm)
    comm.wait(req, max_ticks=max_ticks)
    return req.result


def alltoall(comm: Communicator, sends: Sequence[np.ndarray],
             max_ticks: int = 200_000,
             algorithm: str = "auto") -> List[np.ndarray]:
    """``sends[r][j]`` goes to rank ``j``; returns ``recvs`` with
    ``recvs[r][i] == sends[i][r]`` (personalized exchange)."""
    req = ialltoall(comm, sends, algorithm=algorithm)
    comm.wait(req, max_ticks=max_ticks)
    return req.result


def alltoallv(comm: Communicator,
              blocks: Sequence[Sequence[np.ndarray]],
              max_ticks: int = 200_000,
              algorithm: str = "auto") -> List[List[np.ndarray]]:
    """Variable-size exchange: ``blocks[r][j]`` goes from rank r to rank j;
    returns ``recvs[r][i]`` = block received at r from i (zero-size blocks
    allowed)."""
    req = ialltoallv(comm, blocks, algorithm=algorithm)
    comm.wait(req, max_ticks=max_ticks)
    return req.result


def barrier(comm: Communicator, max_ticks: int = 200_000) -> None:
    """No rank leaves before every rank arrived."""
    comm.wait(ibarrier(comm), max_ticks=max_ticks)
