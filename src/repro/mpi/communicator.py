"""The Communicator: N MPI ranks as nodes of a lossy fabric.

Builds one shared :class:`~repro.core.spin_nic.SpinNIC` (every rank runs
identical execution contexts — eager staging + DDT-unpack offload — so the
jitted datapath compiles once for the whole job), wires one
:class:`MpiHostEngine` per rank into a :class:`~repro.net.fabric.Fabric`,
and maps rank *i* to MAC ``node_mac(i)``.

Progress is explicit, like any discrete-event co-simulation: nonblocking
``isend``/``irecv`` return :class:`Request` handles, and :meth:`wait` /
:meth:`run_until` tick the fabric until they complete.  The blocking
``send``/``recv`` wrappers do the ticking themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core import apps
from repro.core import packet as pkt
from repro.core import spin_nic
from repro.mpi import wire
from repro.mpi.datatypes import DatatypeRegistry
from repro.mpi.engine import (ANY_SOURCE, ANY_TAG, MpiHostEngine, MpiParams,
                              Request)
from repro.net import Fabric, LinkConfig, Node


@dataclasses.dataclass(frozen=True)
class MpiConfig:
    """Tunables of the messaging layer (defaults sized for simulation)."""
    eager_threshold: int = 4096      # >= this (packed, typed) → rendezvous
    eager_slots_per_src: int = 4
    eager_slot_bytes: int = 1 << 15
    n_rdv_slots: int = 4
    slot_quarantine: int = 32        # ticks before a freed eager/rdv slot
    #                                  is reused (late duplicate frames)
    mtu_payload: int = 1024
    slmp_window: int = 16
    slmp_timeout: int = 12
    slmp_max_retries: int = 64
    ctl_timeout: int = 16
    ctl_max_retries: int = 400
    batch: int = 16                  # NIC ingress batch per tick


class Communicator:
    def __init__(self, n_ranks: int,
                 registry: Optional[DatatypeRegistry] = None,
                 link_cfg: LinkConfig = LinkConfig(latency=2),
                 link_cfgs: Optional[Sequence[LinkConfig]] = None,
                 seed: int = 0, cfg: MpiConfig = MpiConfig()):
        assert n_ranks >= 1
        self.n_ranks = n_ranks
        self.cfg = cfg
        self.registry = registry if registry is not None \
            else DatatypeRegistry()
        self.registry.freeze()

        macs = tuple(pkt.node_mac(r) for r in range(n_ranks))
        eager_total = n_ranks * cfg.eager_slots_per_src \
            * cfg.eager_slot_bytes
        rdv_region = max(8, -(-self.registry.max_mem_bytes // 8) * 8)
        contexts = [apps.make_mpi_eager_context(
            wire.EAGER_PORT,
            n_slots=n_ranks * cfg.eager_slots_per_src,
            slot_bytes=cfg.eager_slot_bytes, host_base=0)]
        if len(self.registry):
            maps, lens = self.registry.tables()
            contexts.append(apps.make_mpi_ddt_context(
                maps, lens, region_bytes=rdv_region,
                n_slots=cfg.n_rdv_slots, port=wire.DATA_PORT,
                host_base=eager_total))
        host_bytes = eager_total + cfg.n_rdv_slots * rdv_region

        self.params = MpiParams(
            n_ranks=n_ranks, macs=macs,
            eager_threshold=cfg.eager_threshold,
            eager_slots_per_src=cfg.eager_slots_per_src,
            eager_slot_bytes=cfg.eager_slot_bytes, eager_base=0,
            n_rdv_slots=cfg.n_rdv_slots, rdv_region_bytes=rdv_region,
            rdv_base=eager_total, slot_quarantine=cfg.slot_quarantine,
            mtu_payload=cfg.mtu_payload, slmp_window=cfg.slmp_window,
            slmp_timeout=cfg.slmp_timeout,
            slmp_max_retries=cfg.slmp_max_retries,
            ctl_timeout=cfg.ctl_timeout,
            ctl_max_retries=cfg.ctl_max_retries)

        # one NIC (and one compiled datapath) shared by every rank
        self.nic = spin_nic.SpinNIC(contexts, host_bytes=host_bytes,
                                    batch=cfg.batch)
        self.engines: List[MpiHostEngine] = []
        self.nodes: List[Node] = []
        for r in range(n_ranks):
            engine = MpiHostEngine(r, self.registry, self.params)
            node = Node(f"rank{r}", macs[r], nic=self.nic,
                        engines=[engine])
            engine.attach(node)
            self.engines.append(engine)
            self.nodes.append(node)
        self.link_cfg = link_cfg
        self.link_cfgs = list(link_cfgs) if link_cfgs is not None else None
        self.fabric = Fabric(self.nodes, link_cfg=link_cfg,
                             link_cfgs=self.link_cfgs, seed=seed)

    # ------------------------------------------------------------ plumbing
    @property
    def now(self) -> int:
        return self.fabric.now

    def rewire(self, link_cfg: Optional[LinkConfig] = None,
               link_cfgs: Optional[Sequence[LinkConfig]] = None,
               seed: int = 0) -> None:
        """Fresh engines/NIC-states/links (optionally new link configs)
        without recompiling the shared datapath — sweeps reuse one comm."""
        if link_cfg is not None:
            self.link_cfg = link_cfg
            self.link_cfgs = None
        if link_cfgs is not None:
            self.link_cfgs = list(link_cfgs)
        self.engines = []
        for r, node in enumerate(self.nodes):
            engine = MpiHostEngine(r, self.registry, self.params)
            node.reset(engines=[engine])
            engine.attach(node)
            self.engines.append(engine)
        self.fabric = Fabric(self.nodes, link_cfg=self.link_cfg,
                             link_cfgs=self.link_cfgs, seed=seed)

    def reset(self, seed: int = 0) -> None:
        self.rewire(seed=seed)

    # ------------------------------------------------------- point-to-point
    def isend(self, src: int, dest: int, data: np.ndarray, tag: int = 0,
              datatype=None) -> Request:
        return self.engines[src].isend(dest, data, tag=tag,
                                       datatype=datatype)

    def irecv(self, rank: int, buf: np.ndarray, source: int = ANY_SOURCE,
              tag: int = ANY_TAG) -> Request:
        return self.engines[rank].irecv(buf, source=source, tag=tag)

    def send(self, src: int, dest: int, data: np.ndarray, tag: int = 0,
             datatype=None, max_ticks: int = 100_000) -> Request:
        req = self.isend(src, dest, data, tag=tag, datatype=datatype)
        self.wait(req, max_ticks=max_ticks)
        return req

    def recv(self, rank: int, buf: np.ndarray, source: int = ANY_SOURCE,
             tag: int = ANY_TAG, max_ticks: int = 100_000) -> Request:
        req = self.irecv(rank, buf, source=source, tag=tag)
        self.wait(req, max_ticks=max_ticks)
        return req

    # -------------------------------------------------------------- progress
    def progress(self, ticks: int = 1) -> None:
        for _ in range(ticks):
            self.fabric.tick()

    def run_until(self, predicate: Callable[[], bool],
                  max_ticks: int = 100_000) -> int:
        """Tick the fabric until ``predicate()`` holds.  Raises on engine
        failure (exhausted retries) or timeout."""
        t0 = self.fabric.now
        while not predicate():
            if self.fabric.now - t0 >= max_ticks:
                raise RuntimeError(
                    f"MPI progress timed out after {max_ticks} ticks; "
                    f"engines: " + "; ".join(
                        f"rank{e.rank} done={e.done} stats={e.stats}"
                        for e in self.engines))
            self.fabric.tick()
            for e in self.engines:
                if e.failed:
                    raise RuntimeError("; ".join(e.errors))
        return self.fabric.now - t0

    def wait(self, *reqs: Request, max_ticks: int = 100_000) -> int:
        return self.wait_list(list(reqs), max_ticks=max_ticks)

    def wait_list(self, reqs: List[Request],
                  max_ticks: int = 100_000) -> int:
        """Wait on a (possibly growing) list of requests — collective
        algorithms append follow-on requests from completion callbacks."""
        ticks = self.run_until(lambda: all(r.done for r in reqs),
                               max_ticks=max_ticks)
        errs = [r.error for r in reqs if r.error]
        if errs:
            raise RuntimeError("; ".join(errs))
        return ticks

    # --------------------------------------------------------- observability
    def stats(self) -> List[dict]:
        return [dict(e.stats) for e in self.engines]

    def link_stats(self) -> List[dict]:
        return self.fabric.link_stats()
