"""The Communicator: N MPI ranks as nodes of a lossy fabric.

Builds one shared :class:`~repro.core.spin_nic.SpinNIC` (every rank runs
identical execution contexts — eager staging + DDT-unpack offload — so the
jitted datapath compiles once for the whole job), wires one
:class:`MpiHostEngine` per rank into a :class:`~repro.net.fabric.Fabric`,
and maps rank *i* to MAC ``node_mac(i)``.  NICs are cached job-wide by
(table digest, geometry): a second communicator over the same committed
datatypes reuses the compiled datapath and its uploaded index maps
instead of rebuilding them.

Progress is explicit, like any discrete-event co-simulation: nonblocking
``isend``/``irecv`` return :class:`Request` handles with ``test``/``wait``,
and :meth:`wait` / :meth:`waitall` / :meth:`run_until` tick the fabric
until they complete.  The blocking ``send``/``recv`` wrappers do the
ticking themselves.  Nonblocking collectives register *plans*
(:mod:`repro.mpi.collectives`) whose reactive state rides the same
request layer.

The whole MPI state — fabric, NIC windows, engines mid-protocol, buffer
pool, and active collective plans — is captured by :meth:`checkpoint` and
revived by :meth:`restore`, which accepts a snapshot taken from a
*different* communicator object (same shape) and returns fresh handles
for the collectives that were in flight.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import apps
from repro.core import ddt as ddtlib
from repro.core import packet as pkt
from repro.core import spin_nic
from repro.mpi import wire
from repro.mpi.datatypes import DatatypeRegistry
from repro.mpi.engine import (ANY_SOURCE, ANY_TAG, MpiHostEngine, MpiParams,
                              Request)
from repro.net import Fabric, LinkConfig, Node

# Collectives reserve tags at/above this — keep user tags below it.  Each
# plan owns a block of _PLAN_TAG_SPAN tags (one per algorithm round, or
# one per pipeline segment for the segmented long-message algorithms).
COLL_TAG_BASE = 1 << 20
_PLAN_TAG_SPAN = 4096
_PLAN_TAG_SLOTS = 4096


@dataclasses.dataclass(frozen=True)
class MpiConfig:
    """Tunables of the messaging layer (defaults sized for simulation)."""
    eager_threshold: int = 4096      # >= this (packed, typed) → rendezvous
    eager_slots_per_src: int = 4
    eager_slot_bytes: int = 1 << 15
    n_rdv_slots: int = 4
    slot_quarantine: int = 32        # ticks before a freed eager/rdv slot
    #                                  is reused (late duplicate frames)
    mtu_payload: int = 1024
    slmp_window: int = 16
    slmp_timeout: int = 12
    slmp_max_retries: int = 64
    ctl_timeout: int = 16
    ctl_max_retries: int = 400
    batch: int = 16                  # NIC ingress batch per tick
    coll_seg_bytes: int = 16384      # segment size of the large-message
    #                                  collective fast path: vectors above
    #                                  the eager slot travel as committed
    #                                  contiguous chunks of this size over
    #                                  the credit-managed rendezvous path
    #                                  (0 disables segmentation)


class BufferPool:
    """Identity-preserving buffer registry for checkpointable state.

    Collective plans and posted receives reference numpy buffers by id;
    a snapshot stores one copy per id and a restore rebinds every
    reference to the same fresh array — aliasing (a plan reading the
    buffer an in-flight receive will write) survives the round trip.
    """

    def __init__(self):
        self._bufs: Dict[int, np.ndarray] = {}
        self._next = 0

    def adopt(self, arr: np.ndarray) -> int:
        """Register ``arr`` (stored by reference, not copied)."""
        bid = self._next
        self._next += 1
        self._bufs[bid] = arr
        return bid

    def get(self, bid: int) -> np.ndarray:
        return self._bufs[bid]

    def has(self, bid: int) -> bool:
        return bid in self._bufs

    def release(self, bid: int) -> None:
        self._bufs.pop(bid, None)

    def snapshot(self) -> dict:
        return dict(next=self._next,
                    bufs=[(bid, np.array(a))
                          for bid, a in self._bufs.items()])

    def restore(self, snap: dict) -> None:
        self._next = snap["next"]
        self._bufs = {bid: np.array(a) for bid, a in snap["bufs"]}


# Job-wide NIC cache: a SpinNIC holds no per-node mutable state (NICState
# lives in the Node), so communicators with identical context geometry
# and datatype tables share one compiled datapath — and the device index
# maps upload once per job (apps.MPI_CONTEXT_BUILDS stays flat).
_NIC_CACHE: Dict[tuple, spin_nic.SpinNIC] = {}


def clear_nic_cache() -> None:
    _NIC_CACHE.clear()


class PersistentRequest:
    """A reusable operation binding (MPI_Send_init / MPI_Recv_init).

    ``start()`` posts a fresh :class:`Request` for the bound buffer each
    time it is called; the datatype was resolved to its committed id at
    init time, so repeated ``start()`` calls touch neither the commit
    cache nor the NIC context cache (guarded by a regression test).  The
    buffer is bound by reference — like MPI, the caller refills it
    between ``start()`` calls.
    """

    def __init__(self, comm: "Communicator", kind: str, rank: int,
                 buf: np.ndarray, peer: int, tag: int,
                 dtype_id: Optional[int]):
        self.comm = comm
        self.kind = kind                  # "send" | "recv"
        self.rank = rank
        self.buf = buf
        self.peer = peer                  # dest (send) / source (recv)
        self.tag = tag
        self.dtype_id = dtype_id
        self.active: Optional[Request] = None
        self.starts = 0

    def start(self) -> Request:
        assert self.active is None or self.active.done, \
            "persistent request restarted while still in flight"
        self.starts += 1
        if self.kind == "send":
            req = self.comm.isend(self.rank, self.peer, self.buf,
                                  tag=self.tag, datatype=self.dtype_id)
        else:
            req = self.comm.irecv(self.rank, self.buf, source=self.peer,
                                  tag=self.tag)
        self.active = req
        return req

    def wait(self, max_ticks: int = 100_000) -> Request:
        assert self.active is not None, "start() before wait()"
        self.comm.wait(self.active, max_ticks=max_ticks)
        return self.active


class Communicator:
    def __init__(self, n_ranks: int,
                 registry: Optional[DatatypeRegistry] = None,
                 link_cfg: LinkConfig = LinkConfig(latency=2),
                 link_cfgs: Optional[Sequence[LinkConfig]] = None,
                 seed: int = 0, cfg: MpiConfig = MpiConfig()):
        assert n_ranks >= 1
        self.n_ranks = n_ranks
        self.cfg = cfg
        self.registry = registry if registry is not None \
            else DatatypeRegistry()
        # the large-message collective fast path ships vector segments as
        # committed contiguous chunks through the rendezvous path (NIC
        # unpacks them straight into the destination region) — register
        # the chunk type before the registry freezes so the NIC table has
        # it.  A frozen registry that already carries it is reused; a
        # frozen registry without it disables segmentation.
        self.seg_dtype: Optional[int] = None
        if cfg.coll_seg_bytes:
            seg_ddt = ddtlib.Contiguous(cfg.coll_seg_bytes, ddtlib.MPI_BYTE)
            try:
                self.seg_dtype = self.registry.resolve(seg_ddt)
            except KeyError:
                if not self.registry._frozen:
                    self.seg_dtype = self.registry.register(
                        seg_ddt, name="__coll_seg__")
        self.registry.freeze()

        macs = tuple(pkt.node_mac(r) for r in range(n_ranks))
        eager_total = n_ranks * cfg.eager_slots_per_src \
            * cfg.eager_slot_bytes
        rdv_region = max(8, -(-self.registry.max_mem_bytes // 8) * 8)
        host_bytes = eager_total + cfg.n_rdv_slots * rdv_region

        maps = lens = None
        if len(self.registry):
            maps, lens = self.registry.tables()
        nic_key = (n_ranks, cfg.eager_slots_per_src, cfg.eager_slot_bytes,
                   cfg.n_rdv_slots, cfg.batch, rdv_region, host_bytes,
                   None if maps is None else
                   (maps.tobytes(), lens.tobytes()))
        nic = _NIC_CACHE.get(nic_key)
        if nic is None:
            contexts = [apps.make_mpi_eager_context(
                wire.EAGER_PORT,
                n_slots=n_ranks * cfg.eager_slots_per_src,
                slot_bytes=cfg.eager_slot_bytes, host_base=0)]
            if maps is not None:
                contexts.append(apps.make_mpi_ddt_context(
                    maps, lens, region_bytes=rdv_region,
                    n_slots=cfg.n_rdv_slots, port=wire.DATA_PORT,
                    host_base=eager_total))
            nic = spin_nic.SpinNIC(contexts, host_bytes=host_bytes,
                                   batch=cfg.batch)
            _NIC_CACHE[nic_key] = nic

        self.params = MpiParams(
            n_ranks=n_ranks, macs=macs,
            eager_threshold=cfg.eager_threshold,
            eager_slots_per_src=cfg.eager_slots_per_src,
            eager_slot_bytes=cfg.eager_slot_bytes, eager_base=0,
            n_rdv_slots=cfg.n_rdv_slots, rdv_region_bytes=rdv_region,
            rdv_base=eager_total, slot_quarantine=cfg.slot_quarantine,
            mtu_payload=cfg.mtu_payload, slmp_window=cfg.slmp_window,
            slmp_timeout=cfg.slmp_timeout,
            slmp_max_retries=cfg.slmp_max_retries,
            ctl_timeout=cfg.ctl_timeout,
            ctl_max_retries=cfg.ctl_max_retries)

        # one NIC (and one compiled datapath) shared by every rank
        self.nic = nic
        self.pool = BufferPool()
        self._plans: Dict[int, "object"] = {}
        self._next_plan_id = 0
        self.engines: List[MpiHostEngine] = []
        self.nodes: List[Node] = []
        for r in range(n_ranks):
            engine = MpiHostEngine(r, self.registry, self.params,
                                   pool=self.pool)
            node = Node(f"rank{r}", macs[r], nic=self.nic,
                        engines=[engine])
            engine.attach(node)
            self.engines.append(engine)
            self.nodes.append(node)
        self.link_cfg = link_cfg
        self.link_cfgs = list(link_cfgs) if link_cfgs is not None else None
        self.fabric = Fabric(self.nodes, link_cfg=link_cfg,
                             link_cfgs=self.link_cfgs, seed=seed)

    # ------------------------------------------------------------ plumbing
    @property
    def now(self) -> int:
        return self.fabric.now

    def rewire(self, link_cfg: Optional[LinkConfig] = None,
               link_cfgs: Optional[Sequence[LinkConfig]] = None,
               seed: int = 0) -> None:
        """Fresh engines/NIC-states/links (optionally new link configs)
        without recompiling the shared datapath — sweeps reuse one comm."""
        if link_cfg is not None:
            self.link_cfg = link_cfg
            self.link_cfgs = None
        if link_cfgs is not None:
            self.link_cfgs = list(link_cfgs)
        self.pool = BufferPool()
        self._plans = {}
        self._next_plan_id = 0
        self.engines = []
        for r, node in enumerate(self.nodes):
            engine = MpiHostEngine(r, self.registry, self.params,
                                   pool=self.pool)
            node.reset(engines=[engine])
            engine.attach(node)
            self.engines.append(engine)
        self.fabric = Fabric(self.nodes, link_cfg=self.link_cfg,
                             link_cfgs=self.link_cfgs, seed=seed)

    def reset(self, seed: int = 0) -> None:
        self.rewire(seed=seed)

    # ------------------------------------------------------- point-to-point
    def isend(self, src: int, dest: int, data: np.ndarray, tag: int = 0,
              datatype=None) -> Request:
        req = self.engines[src].isend(dest, data, tag=tag,
                                      datatype=datatype)
        req._comm = self
        return req

    def irecv(self, rank: int, buf: np.ndarray, source: int = ANY_SOURCE,
              tag: int = ANY_TAG, buf_id: Optional[int] = None) -> Request:
        req = self.engines[rank].irecv(buf, source=source, tag=tag,
                                       buf_id=buf_id)
        req._comm = self
        return req

    # -------------------------------------------------- persistent requests
    def send_init(self, src: int, dest: int, data: np.ndarray,
                  tag: int = 0, datatype=None) -> "PersistentRequest":
        """MPI_Send_init: bind (buffer, peer, tag, datatype) once; every
        :meth:`PersistentRequest.start` posts a fresh transfer reusing the
        committed datatype plan (resolved here, once) and the job-cached
        NIC contexts — no recommit, no re-upload, no registry lookup on
        the per-iteration path."""
        dtype_id = None if datatype is None \
            else self.registry.resolve(datatype)
        return PersistentRequest(self, "send", src, data, dest, tag,
                                 dtype_id)

    def recv_init(self, rank: int, buf: np.ndarray,
                  source: int = ANY_SOURCE,
                  tag: int = ANY_TAG) -> "PersistentRequest":
        """MPI_Recv_init: the receive-side half of a persistent pair."""
        return PersistentRequest(self, "recv", rank, buf, source, tag,
                                 None)

    def start_all(self, preqs: Sequence["PersistentRequest"]
                  ) -> List[Request]:
        """MPI_Startall over persistent handles."""
        return [p.start() for p in preqs]

    def send(self, src: int, dest: int, data: np.ndarray, tag: int = 0,
             datatype=None, max_ticks: int = 100_000) -> Request:
        req = self.isend(src, dest, data, tag=tag, datatype=datatype)
        self.wait(req, max_ticks=max_ticks)
        return req

    def recv(self, rank: int, buf: np.ndarray, source: int = ANY_SOURCE,
             tag: int = ANY_TAG, max_ticks: int = 100_000) -> Request:
        req = self.irecv(rank, buf, source=source, tag=tag)
        self.wait(req, max_ticks=max_ticks)
        return req

    # -------------------------------------------------------------- progress
    def progress(self, ticks: int = 1) -> None:
        for _ in range(ticks):
            self.fabric.tick()

    def run_until(self, predicate: Callable[[], bool],
                  max_ticks: int = 100_000) -> int:
        """Tick the fabric until ``predicate()`` holds.  Raises on engine
        failure (exhausted retries) or timeout."""
        t0 = self.fabric.now
        while not predicate():
            if self.fabric.now - t0 >= max_ticks:
                raise RuntimeError(
                    f"MPI progress timed out after {max_ticks} ticks; "
                    f"engines: " + "; ".join(
                        f"rank{e.rank} done={e.done} stats={e.stats}"
                        for e in self.engines))
            self.fabric.tick()
            for e in self.engines:
                if e.failed:
                    raise RuntimeError("; ".join(e.errors))
        return self.fabric.now - t0

    def test(self, *reqs: Request) -> bool:
        """MPI_Testall: True iff every request is complete.  Never ticks."""
        return all(r.done for r in reqs)

    def wait(self, *reqs: Request, max_ticks: int = 100_000) -> int:
        return self.waitall(list(reqs), max_ticks=max_ticks)

    def waitall(self, reqs: List[Request],
                max_ticks: int = 100_000) -> int:
        """Wait on a (possibly growing) list of requests — collective
        algorithms append follow-on requests from completion callbacks."""
        ticks = self.run_until(lambda: all(r.done for r in reqs),
                               max_ticks=max_ticks)
        errs = [r.error for r in reqs if r.error]
        if errs:
            raise RuntimeError("; ".join(errs))
        return ticks

    # kept as an alias — collective plans and older call sites use it
    wait_list = waitall

    # ------------------------------------------------------ collective plans
    def _new_plan_slot(self):
        pid = self._next_plan_id
        self._next_plan_id += 1
        tag_base = COLL_TAG_BASE \
            + (pid % _PLAN_TAG_SLOTS) * _PLAN_TAG_SPAN
        return pid, tag_base

    def _register_plan(self, pid: int, plan) -> None:
        self._plans[pid] = plan

    def _unregister_plan(self, pid: int) -> None:
        self._plans.pop(pid, None)

    # --------------------------------------------------------- observability
    def stats(self) -> List[dict]:
        return [dict(e.stats) for e in self.engines]

    def link_stats(self) -> List[dict]:
        return self.fabric.link_stats()

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> dict:
        """Snapshot the whole MPI state: fabric (links, NIC windows, clock,
        PRNG) via its existing checkpoint path — which recurses into every
        engine's closure-free snapshot — plus the buffer pool and every
        active collective plan.  Read-only: the live run is unperturbed."""
        return dict(
            fabric=self.fabric.checkpoint(),
            pool=self.pool.snapshot(),
            plans=[(pid, p.snapshot()) for pid, p in self._plans.items()],
            next_plan_id=self._next_plan_id,
        )

    def restore(self, snap: dict) -> Dict[int, Request]:
        """Revive a checkpoint into *this* communicator (freshly built with
        the same shape, or the original).  Returns fresh collective handles
        keyed by plan id — the collectives that were in flight at snapshot
        time complete on these."""
        from repro.mpi import collectives as coll   # avoid import cycle
        self.pool.restore(snap["pool"])
        self.fabric.restore(snap["fabric"])
        self._next_plan_id = snap["next_plan_id"]
        self._plans = {}
        handles: Dict[int, Request] = {}
        for pid, ps in snap["plans"]:
            plan = coll.PLAN_TYPES[ps["name"]].from_snapshot(self, pid, ps)
            self._plans[pid] = plan
            handles[pid] = plan.request
        # re-attach plan completion callbacks to the live requests the
        # engine snapshots revived (matched by collective token)
        for e in self.engines:
            for req in list(e._reqs.values()):
                req._comm = self
                if req.ctoken is None:
                    continue
                pid, key = req.ctoken
                plan = self._plans.get(pid)
                if plan is not None:
                    req.add_done_callback(
                        lambda q, plan=plan, key=key: plan._step(key, q))
        return handles
