"""Benchmark harness — one module per paper table/figure.

  bench_pingpong : Fig 7  (RTT, 3 modes × ICMP/UDP × payload)
  bench_slmp     : Fig 8  (throughput vs window size, failures)
  bench_fabric   : Fig 8 over the net fabric (loss × window goodput sweep,
                   ping-pong latency vs loss) — also writes the
                   machine-readable ``BENCH_fabric.json``
  bench_ddt      : Fig 10 (DDT throughput + overlap ratio)
  bench_latency  : Table II (module latencies)
  bench_kernels  : Pallas kernel micro-benchmarks

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_ddt, bench_fabric, bench_kernels,
                            bench_latency, bench_pingpong, bench_slmp)
    suites = [
        ("fig7_pingpong", bench_pingpong.run),
        ("fig8_slmp", bench_slmp.run),
        ("fabric", bench_fabric.run),
        ("fig10_ddt", bench_ddt.run),
        ("table2_latency", bench_latency.run),
        ("kernels", bench_kernels.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.time()
        print(f"# --- {name} ---")
        fn()
        print(f"# {name} done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
