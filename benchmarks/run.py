"""Benchmark harness — one module per paper table/figure.

  bench_pingpong : Fig 7  (RTT, 3 modes × ICMP/UDP × payload)
  bench_slmp     : Fig 8  (throughput vs window size, failures)
  bench_fabric   : Fig 8 over the net fabric (loss × window goodput sweep,
                   ping-pong latency vs loss) — also writes the
                   machine-readable ``BENCH_fabric.json``
  bench_mpi      : Fig 10 end-to-end (MPI datatype offload overlap ratio
                   through the lossy fabric, collective goodput vs node
                   count) — writes ``BENCH_mpi.json``
  bench_ddt      : Fig 10 (DDT throughput + overlap ratio, single NIC)
  bench_latency  : Table II (module latencies)
  bench_kernels  : Pallas kernel micro-benchmarks

Usage: ``python -m benchmarks.run [filter] [--filter SCENARIO]`` runs
every suite whose name contains ``filter`` (all when omitted);
``--filter SCENARIO`` additionally restricts suites that define scenarios
(currently ``bench_mpi``) to the scenarios whose name contains SCENARIO
— e.g. ``python -m benchmarks.run mpi --filter allreduce_large`` is the
CI smoke for the large-message fast path.  ``--list`` prints the suite
names.  A filter matching nothing is an error, not a silent no-op.

Suites that write ``BENCH_*.json`` stamp each record with the scenario
name and its harness wall-clock seconds (``harness_seconds``), so a
simulator slowdown is visible across PRs even when modeled ticks stay
flat.

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import inspect
import sys
import time


def main() -> None:
    from benchmarks import (bench_ddt, bench_fabric, bench_kernels,
                            bench_latency, bench_mpi, bench_pingpong,
                            bench_slmp)
    suites = [
        ("fig7_pingpong", bench_pingpong.run),
        ("fig8_slmp", bench_slmp.run),
        ("fabric", bench_fabric.run),
        ("fig10_ddt", bench_ddt.run),
        ("mpi", bench_mpi.run),
        ("table2_latency", bench_latency.run),
        ("kernels", bench_kernels.run),
    ]
    args = sys.argv[1:]
    scenario = None
    if "--filter" in args:
        i = args.index("--filter")
        assert i + 1 < len(args), "--filter needs a scenario name"
        scenario = args[i + 1]
        args = args[:i] + args[i + 2:]
    only = args[0] if args else None
    if only in ("--list", "-l"):
        for name, _ in suites:
            print(name)
        return
    selected = [(n, fn) for n, fn in suites if not only or only in n]
    if not selected:
        sys.exit(f"no benchmark suite matches {only!r}; available: "
                 + ", ".join(n for n, _ in suites))
    if scenario is not None:
        selected = [(n, fn) for n, fn in selected
                    if "scenario_filter" in inspect.signature(fn).parameters]
        if not selected:
            sys.exit(f"--filter {scenario!r} matches no suite that "
                     f"defines scenarios")
    print("name,us_per_call,derived")
    for name, fn in selected:
        t0 = time.time()
        print(f"# --- {name} ---")
        if "scenario_filter" in inspect.signature(fn).parameters:
            fn(scenario_filter=scenario)
        else:
            fn()
        print(f"# {name} done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
