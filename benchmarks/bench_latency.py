"""Paper Table II: per-module datapath latency.

Two columns:
  * the paper's RTL-derived numbers, reproduced verbatim from the
    analytic hardware model (cycles, clock, ns);
  * measured per-packet wall-clock of this implementation's corresponding
    vectorized module (batch cost / batch size) — the TPU-adapted
    equivalents run three orders of magnitude more packets per invocation,
    which is the point of the adaptation.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import alloc as palloc
from repro.core import her as herlib
from repro.core import hwmodel, matching, packet as pkt

BATCH = 256


def run() -> None:
    # ---- paper Table II from the model
    for mod, info in hwmodel.table2().items():
        ns = info["ns"]
        ns_str = (f"{ns[0]:.0f}-{ns[1]:.0f}" if isinstance(ns, tuple)
                  else f"{ns:.0f}")
        row(f"table2_{mod}", 0.0,
            f"cycles={info['cycles']};mhz={info['mhz']};ns={ns_str}")

    rng = np.random.default_rng(0)
    frames = [pkt.make_udp(rng.integers(0, 256, 64).astype(np.uint8),
                           dport=9999) for _ in range(BATCH)]
    batch = pkt.stack_frames(frames)
    tables = matching.MatchTables.build([matching.ruleset_udp_pingpong()])

    # ---- matching engine
    match = jax.jit(lambda b: matching.match_batch(b, tables)[0])
    t = time_fn(match, batch)
    row("module_matching_engine", t / BATCH * 1e6,
        f"paper_ns={hwmodel.match_ns():.0f}")

    # ---- allocator
    st = palloc.make_state()
    alloc_fn = jax.jit(
        lambda s, ln, v: palloc.alloc(s, ln, v)[1])
    t = time_fn(alloc_fn, st, batch.length, batch.valid)
    row("module_allocator", t / BATCH * 1e6, "paper_ns=0")

    # ---- ingress DMA (L2 scatter)
    l2 = jnp.zeros((palloc.L2_PKT_BYTES,), jnp.uint8)
    addr = jnp.arange(BATCH, dtype=jnp.int32) * pkt.MTU % palloc.LARGE_BASE

    def ingress(l2, data, addr):
        off = addr[:, None] + jnp.arange(pkt.MTU, dtype=jnp.int32)[None]
        return l2.at[off.reshape(-1)].set(data.reshape(-1), mode="drop")

    t = time_fn(jax.jit(ingress), l2, batch.data, addr)
    row("module_ingress_dma", t / BATCH * 1e6,
        f"paper_ns={hwmodel.ingress_dma_ns(64):.0f}-"
        f"{hwmodel.ingress_dma_ns(1536):.0f}")

    # ---- HER generator + MPQ scheduling
    mpq = herlib.make_mpq()
    her_fn = jax.jit(lambda m, c, a, s, i, e, v:
                     herlib.generate(m, c, a, s, i, e, v)[1].lane)
    ctx = jnp.zeros((BATCH,), jnp.int32)
    msg = jnp.arange(BATCH, dtype=jnp.uint32) % 8
    eom = jnp.zeros((BATCH,), bool)
    t = time_fn(her_fn, mpq, ctx, addr, batch.length, msg, eom,
                batch.valid)
    row("module_her_generator", t / BATCH * 1e6, "paper_ns=0")

    # ---- host DMA (byte-granular scatter, unaligned-capable)
    host = jnp.zeros((1 << 20,), jnp.uint8)

    def hostdma(host, data):
        off = (jnp.arange(BATCH)[:, None] * 1536 + 3        # unaligned +3
               + jnp.arange(pkt.MTU, dtype=jnp.int32)[None])
        return host.at[off.reshape(-1)].set(data.reshape(-1), mode="drop")

    t = time_fn(jax.jit(hostdma), host, batch.data)
    row("module_host_dma", t / BATCH * 1e6,
        f"paper_ns={hwmodel.HOST_DMA_NS}")


if __name__ == "__main__":
    run()
