"""Fabric sweep: SLMP goodput vs (loss rate × window size), plus ping-pong
latency vs loss — the paper's Fig 8 shape, but over an actual lossy,
reordering wire with the retransmission path live.

Each point runs a real two-node fabric: the host-side SLMP state machine
windows and retransmits, the receiver runs the sPIN handler pipeline.
Time is counted in fabric ticks; a tick is mapped to wall time via
``TICK_NS`` calibrated so the fabric RTT (2 ticks each way at latency=2)
matches the 30 us loopback RTT used by bench_slmp — goodput numbers are
therefore in the same modeled 100G setting, not this host's speed.

Writes every point to ``BENCH_fabric.json`` (machine-readable perf
trajectory) in addition to the CSV rows.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

import numpy as np

from benchmarks.common import row, timed_scenario
from repro.core import apps, packet as pkt, slmp
from repro.net import Fabric, LinkConfig, Node, PingPongClient, \
    SlmpSenderEngine

LOSSES = [0.0, 0.05, 0.1, 0.2]
WINDOWS = [4, 16, 64]
MSG_BYTES = 1 << 16                     # 64 KiB per transfer
MTU_PAYLOAD = 1024
BATCH = 32
TICK_NS = 7_500.0                       # 4-tick RTT == 30 us (bench_slmp)
JSON_PATH = "BENCH_fabric.json"


def _goodput_sweep(tx: Node, rx: Node, msg: np.ndarray) -> List[dict]:
    records = []
    for loss in LOSSES:
        for window in WINDOWS:
            cfg = slmp.SlmpSenderConfig(
                window=window, mtu_payload=MTU_PAYLOAD, timeout=12,
                max_retries=64, src_mac=pkt.node_mac(0),
                dst_mac=pkt.node_mac(1))
            sender = SlmpSenderEngine(msg, msg_id=1, cfg=cfg)
            tx.reset(engines=[sender])
            rx.reset()
            fab = Fabric([tx, rx],
                         link_cfg=LinkConfig(loss=loss, latency=2,
                                             jitter=2), seed=11)
            ticks = fab.run(max_ticks=50_000)
            delivered = sender.done and bool(
                (rx.read_host(0, len(msg)) == msg).all())
            t_ns = ticks * TICK_NS
            gbps = len(msg) * 8 / t_ns if delivered else 0.0
            s = sender.sender
            fstats = fab.stats()
            wire = fstats["links"][1]
            rec = dict(kind="slmp_goodput", loss=loss, window=window,
                       ticks=ticks, delivered=delivered,
                       segments=s.nseg, sent_frames=s.sent_frames,
                       retransmits=s.retransmits,
                       goodput_gbps=round(gbps, 3),
                       unroutable=fstats["unroutable"],
                       wire=wire)
            records.append(rec)
            # per-link drop/duplicate/reorder/stall counters (and the
            # fabric's unroutable count) make loss-sweep anomalies
            # diagnosable from the CSV alone
            row(f"fabric_slmp_loss{int(loss * 100)}_w{window}",
                t_ns / 1e3,
                f"gbps={gbps:.2f};retx={s.retransmits};"
                f"delivered={delivered};lost={wire['lost']};"
                f"dup={wire['duplicated']};reo={wire['reordered']};"
                f"ovfl={wire['overflowed']};defer={wire['deferred']};"
                f"unroutable={fstats['unroutable']}")
    return records


def _latency_sweep(server_ctx) -> List[dict]:
    records = []
    server = Node("server", pkt.node_mac(1), [server_ctx], batch=8)
    client_node = Node("client", pkt.node_mac(0),
                       [apps.make_null_context()], batch=8)
    for loss in LOSSES:
        client = PingPongClient(count=8, proto="udp",
                                src_mac=pkt.node_mac(0),
                                dst_mac=pkt.node_mac(1), timeout=16)
        client_node.reset(engines=[client])
        server.reset()
        fab = Fabric([client_node, server],
                     link_cfg=LinkConfig(loss=loss, latency=1), seed=4)
        fab.run(max_ticks=5_000)
        rtts = client.rtts
        mean_ticks = float(np.mean(rtts)) if rtts else float("nan")
        fstats = fab.stats()
        wire = fstats["links"][1]
        rec = dict(kind="pingpong_latency", loss=loss,
                   completed=len(rtts), timeouts=client.timeouts,
                   mean_rtt_ticks=mean_ticks,
                   mean_rtt_us=round(mean_ticks * TICK_NS / 1e3, 2),
                   unroutable=fstats["unroutable"],
                   wire=wire)
        records.append(rec)
        row(f"fabric_pingpong_loss{int(loss * 100)}",
            mean_ticks * TICK_NS / 1e3,
            f"rtt_ticks={mean_ticks:.1f};timeouts={client.timeouts};"
            f"lost={wire['lost']};dup={wire['duplicated']};"
            f"reo={wire['reordered']};defer={wire['deferred']};"
            f"unroutable={fstats['unroutable']}")
    return records


def run(json_path: Optional[str] = JSON_PATH) -> List[dict]:
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 256, MSG_BYTES).astype(np.uint8)
    tx = Node("tx", pkt.node_mac(0), [apps.make_null_context()],
              batch=BATCH)
    rx = Node("rx", pkt.node_mac(1), [slmp.make_slmp_context()],
              batch=BATCH, host_bytes=1 << 17)
    records: List[dict] = []
    timed_scenario("slmp_goodput",
                   lambda recs: recs.extend(_goodput_sweep(tx, rx, msg)),
                   records)
    timed_scenario("pingpong_latency",
                   lambda recs: recs.extend(
                       _latency_sweep(apps.make_udp_pingpong_context())),
                   records)
    if json_path:
        payload = dict(bench="fabric", tick_ns=TICK_NS,
                       msg_bytes=MSG_BYTES, records=records)
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        row("fabric_json", 0.0, f"wrote={os.path.abspath(json_path)};"
            f"points={len(records)}")
    return records


if __name__ == "__main__":
    run()
