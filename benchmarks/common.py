"""Shared benchmark utilities: timing, CSV row emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock seconds per call (blocking on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line)
    return line


def timed_scenario(name: str, fn: Callable, records: list,
                   *args, **kw) -> None:
    """Run one benchmark scenario and stamp its harness wall-clock seconds
    into every record it appended — a perf trajectory for the *harness*
    itself, so a simulator slowdown is visible across PRs even when the
    modeled tick numbers stay flat."""
    n0 = len(records)
    t0 = time.perf_counter()
    fn(records, *args, **kw)
    dt = round(time.perf_counter() - t0, 2)
    for rec in records[n0:]:
        rec.setdefault("scenario", name)
        rec["harness_seconds"] = dt
    row(f"scenario_{name}_wall", dt * 1e6, f"records={len(records) - n0}")
