"""Paper Fig 7: ICMP/UDP ping-pong RTT in Host / FPsPIN / Host+FPsPIN
modes across payload sizes.

Two measurement columns per point:
  * measured — wall-clock through this implementation (vectorized NIC on
    this host; per-packet cost = batch cost / batch size);
  * model_ns — the paper-faithful analytic FPGA model (core/hwmodel.py,
    built from Table II constants + Fig 7 calibration), i.e. what the
    40 MHz FPsPIN prototype would measure.
Each point also emits a ``pingpong_fabric_*`` row: an end-to-end
functional check through the two-node net fabric (client engine on node
0, responder handlers on node 1's sNIC).  The fabric is tick-granular,
so at loss=0 the RTT is the constant 2-tick wire time regardless of
payload — the row asserts all pongs complete, it is not a latency
measurement (bench_fabric sweeps fabric latency vs loss).

The qualitative claims being reproduced: UDP offload beats the host stack;
ICMP RTT grows linearly with payload (checksum-dominated); Host mode ICMP
stays flat (optimized kernel checksum); Host+FPsPIN sits between.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import row, time_fn
from repro.core import apps, checksum, hwmodel, packet as pkt, spin_nic
from repro.net import Fabric, LinkConfig, Node, PingPongClient

PAYLOADS = [56, 256, 512, 1024]
BATCH = 64
FABRIC_PINGS = 8


def _np_host_respond_icmp(frames):
    """Host mode: per-packet kernel-stack responder (numpy, optimized
    vectorized checksum — the kernel's csum is highly tuned)."""
    out = []
    for f in frames:
        g = f.copy()
        g[pkt.ETH_DST:pkt.ETH_DST + 6], g[pkt.ETH_SRC:pkt.ETH_SRC + 6] = \
            f[pkt.ETH_SRC:pkt.ETH_SRC + 6].copy(), \
            f[pkt.ETH_DST:pkt.ETH_DST + 6].copy()
        g[pkt.IP_SRC:pkt.IP_SRC + 4], g[pkt.IP_DST:pkt.IP_DST + 4] = \
            f[pkt.IP_DST:pkt.IP_DST + 4].copy(), \
            f[pkt.IP_SRC:pkt.IP_SRC + 4].copy()
        g[pkt.ICMP_TYPE] = 0
        g[pkt.ICMP_CSUM:pkt.ICMP_CSUM + 2] = 0
        c = pkt.internet_checksum_np(g[pkt.L4_BASE:])
        g[pkt.ICMP_CSUM] = c >> 8
        g[pkt.ICMP_CSUM + 1] = c & 0xFF
        out.append(g)
    return out


def run() -> None:
    rng = np.random.default_rng(0)
    client_node = Node("client", pkt.node_mac(0),
                       [apps.make_null_context()], batch=8)
    servers = {
        "icmp": Node("icmp_srv", pkt.node_mac(1),
                     [apps.make_icmp_context()], batch=8),
        "udp": Node("udp_srv", pkt.node_mac(1),
                    [apps.make_udp_pingpong_context()], batch=8),
    }
    for proto in ("icmp", "udp"):
        for payload in PAYLOADS:
            data = rng.integers(0, 256, payload).astype(np.uint8)
            mk = (pkt.make_icmp_echo if proto == "icmp" else
                  lambda p: pkt.make_udp(p, dport=9999))
            frames = [mk(data) for _ in range(BATCH)]
            batch = pkt.stack_frames(frames)

            # ---- Host mode: everything in the host responder
            t = time_fn(lambda: _np_host_respond_icmp(frames)
                        if proto == "icmp" else
                        [f.copy() for f in frames], iters=5) / BATCH
            model = hwmodel.pingpong_rtt_ns("host", proto, payload)
            row(f"pingpong_host_{proto}_{payload}B", t * 1e6,
                f"model_ns={model.total_ns:.0f}")

            # ---- FPsPIN mode: offloaded handler does everything
            ctx = (apps.make_icmp_context() if proto == "icmp"
                   else apps.make_udp_pingpong_context())
            nic = spin_nic.SpinNIC([ctx], batch=BATCH)
            cell = {"st": nic.init_state()}

            def fp_step():
                # NIC state is donated: thread it through the cell
                s2, eg, _ = nic.step(cell["st"], batch)
                cell["st"] = s2
                return eg.valid

            t = time_fn(fp_step, iters=5) / BATCH
            model = hwmodel.pingpong_rtt_ns("fpspin", proto, payload)
            row(f"pingpong_fpspin_{proto}_{payload}B", t * 1e6,
                f"model_ns={model.total_ns:.0f}")

            # ---- FPsPIN mode, end-to-end over the two-node fabric
            client = PingPongClient(count=FABRIC_PINGS, payload=payload,
                                    proto=proto,
                                    src_mac=pkt.node_mac(0),
                                    dst_mac=pkt.node_mac(1))
            client_node.reset(engines=[client])
            servers[proto].reset()
            fab = Fabric([client_node, servers[proto]],
                         link_cfg=LinkConfig(loss=0.0, latency=1), seed=0)
            fab.run(max_ticks=1_000)
            rtt = float(np.mean(client.rtts)) if client.rtts else -1.0
            row(f"pingpong_fabric_{proto}_{payload}B", 0.0,
                f"fabric_rtt_ticks={rtt:.1f};"
                f"pongs={len(client.rtts)}/{FABRIC_PINGS}")
            assert len(client.rtts) == FABRIC_PINGS, \
                f"fabric pingpong incomplete: {len(client.rtts)}"

            # ---- Host+FPsPIN: NIC matches + DMAs to host; host checksums
            nic2 = spin_nic.SpinNIC([apps.make_icmp_host_context()],
                                    batch=BATCH, host_bytes=1 << 20)
            cell2 = {"st": nic2.init_state()}

            def hybrid():
                s2, _, _ = nic2.step(cell2["st"], batch)
                cell2["st"] = s2
                if proto == "icmp":               # host-side checksum
                    buf = np.asarray(s2.host[: BATCH * pkt.MTU])
                    _ = pkt.internet_checksum_np(buf[:payload + 8])
                return s2.cycles

            t = time_fn(hybrid, iters=5) / BATCH
            model = hwmodel.pingpong_rtt_ns("host+fpspin", proto, payload)
            row(f"pingpong_hostfpspin_{proto}_{payload}B", t * 1e6,
                f"model_ns={model.total_ns:.0f}")

    # structural check recorded as derived fields
    m_udp_host = hwmodel.pingpong_rtt_ns("host", "udp", 56).total_ns
    m_udp_fp = hwmodel.pingpong_rtt_ns("fpspin", "udp", 56).total_ns
    m_icmp_1k = hwmodel.pingpong_rtt_ns("fpspin", "icmp", 1024).total_ns
    m_icmp_56 = hwmodel.pingpong_rtt_ns("fpspin", "icmp", 56).total_ns
    row("pingpong_model_checks", 0.0,
        f"udp_offload_speedup={m_udp_host / m_udp_fp:.2f};"
        f"icmp_slope_ns_per_B="
        f"{(m_icmp_1k - m_icmp_56) / (1024 - 56):.1f}")


if __name__ == "__main__":
    run()
