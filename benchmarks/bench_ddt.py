"""Paper Fig 10: MPI DDT processing throughput + overlap ratio.

Measures, for the "simple" and "complex" Fig-9 datatypes across message
sizes:
  * offloaded DDT unpack throughput (the committed-index-map gather —
    the SpinIngest device path);
  * the same with an overlapping matrix multiplication sized to run
    slightly longer than the transfer (paper's methodology);
  * overlap ratio  R = T_MM / (T_MM + T_Poll)  via double-buffered
    dispatch (core/overlap.py) — the paper's headline 96–98 %.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import ddt as ddtlib, overlap
from repro.kernels.ddt import ops as ddt_ops

COUNTS = {"simple": [64, 256, 1024], "complex": [64, 256, 1024]}
MM_DIMS = [128, 192, 256, 384, 512, 768, 1024]   # calibration ladder


def run() -> None:
    rng = np.random.default_rng(0)
    for name in ("simple", "complex"):
        base = (ddtlib.simple_ddt() if name == "simple"
                else ddtlib.complex_ddt())
        for count in COUNTS[name]:
            c = ddtlib.commit(base, count=count)
            pack_idx, unpack_idx = ddtlib.element_maps(c, 4)
            pack_idx = jnp.asarray(pack_idx)
            unpack_idx = jnp.asarray(unpack_idx)
            msg = jnp.asarray(
                rng.normal(size=c.msg_bytes // 4).astype(np.float32))
            dst = jnp.zeros((c.mem_bytes // 4,), jnp.float32)

            unpack = jax.jit(
                lambda m, d: ddt_ops.unpack(m, unpack_idx, d))
            t = time_fn(unpack, msg, dst)
            gbps = c.msg_bytes * 8 / max(t, 1e-9) / 1e9
            row(f"ddt_unpack_{name}_{c.msg_bytes >> 10}KB", t * 1e6,
                f"gbps={gbps:.2f}")

            # ---- overlap with a matmul (paper Fig 10 methodology):
            # "we tune the size of the computation so that it lasts
            # slightly longer than the data transfer"
            def ingest(m):
                return unpack(m, dst)

            t_ingest = time_fn(ingest, msg, iters=5)
            mm_dim = MM_DIMS[-1]
            for dim in MM_DIMS:
                wtest = jnp.zeros((dim, dim), jnp.float32)
                t_mm = time_fn(jax.jit(lambda a: a @ a), wtest, iters=3)
                if t_mm >= 1.2 * t_ingest:
                    mm_dim = dim
                    break
            w = jnp.asarray(rng.normal(size=(mm_dim, mm_dim))
                            .astype(np.float32))

            def compute(state, batch):
                # "host" compute: matmul chained on its own state only
                return state @ w / mm_dim

            feeds = [msg] * 12
            state0 = jnp.eye(mm_dim, dtype=jnp.float32)
            _, seq = overlap.sequential_loop(ingest, compute, feeds,
                                             state0)
            _, ov = overlap.overlapped_loop(ingest, compute, feeds,
                                            state0)
            row(f"ddt_overlap_{name}_{c.msg_bytes >> 10}KB",
                ov.wall_s / len(feeds) * 1e6,
                f"R={ov.overlap_ratio:.4f};R_seq={seq.overlap_ratio:.4f};"
                f"speedup={seq.wall_s / ov.wall_s:.2f};mm_dim={mm_dim}")


if __name__ == "__main__":
    run()
