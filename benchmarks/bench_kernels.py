"""Kernel micro-benchmarks: Pallas (interpret) correctness-path cost vs the
jnp reference path that serves CPU hot paths.  On real TPU the Pallas path
compiles via Mosaic; interpret mode here is the correctness oracle, so the
derived field records validation, not speed."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import packet as pkt
from repro.kernels.checksum import ops as cops
from repro.kernels.ddt import ops as dops
from repro.kernels.matcher import ops as mops
from repro.core import matching


def run() -> None:
    rng = np.random.default_rng(0)

    # ddt gather: 1 MiB message
    s = 1 << 18
    src = jnp.asarray(rng.normal(size=s).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, s, size=s).astype(np.int32))
    ref = jax.jit(lambda a, b: dops.gather(a, b, use_kernel=False))
    t = time_fn(ref, src, idx)
    gbps = s * 4 * 8 / t / 1e9
    ok = np.array_equal(np.asarray(dops.gather(src[:4096], idx[:4096] % 4096,
                                               use_kernel=True)),
                        np.asarray(dops.gather(src[:4096], idx[:4096] % 4096,
                                               use_kernel=False)))
    row("kernel_ddt_gather_1MB", t * 1e6,
        f"ref_gbps={gbps:.2f};pallas_interpret_ok={ok}")

    # checksum over 256 MTU frames
    frames = [pkt.make_icmp_echo(rng.integers(0, 256, 1024).astype(np.uint8))
              for _ in range(256)]
    b = pkt.stack_frames(frames)
    ref = jax.jit(lambda d, ln: cops.internet_checksum(
        d, ln, start=pkt.L4_BASE, use_kernel=False))
    t = time_fn(ref, b.data, b.length)
    ok = np.array_equal(
        np.asarray(cops.internet_checksum(b.data[:32], b.length[:32],
                                          start=pkt.L4_BASE,
                                          use_kernel=True)),
        np.asarray(cops.internet_checksum(b.data[:32], b.length[:32],
                                          start=pkt.L4_BASE,
                                          use_kernel=False)))
    row("kernel_checksum_256pkt", t * 1e6,
        f"ref_gbps={256 * 1024 * 8 / t / 1e9:.2f};pallas_interpret_ok={ok}")

    # matcher over 1024 packets × 3 contexts
    frames = [pkt.make_udp(np.zeros(64, np.uint8), dport=9999)
              for _ in range(1024)]
    b = pkt.stack_frames(frames)
    tables = matching.MatchTables.build(
        [matching.ruleset_icmp_echo(), matching.ruleset_udp_pingpong(9999),
         matching.ruleset_slmp()])
    words = b.words()
    ref = jax.jit(lambda w: mops.match(w, tables.rules, tables.modes,
                                       use_kernel=False)[0])
    t = time_fn(ref, words)
    mk, _ = mops.match(words[:128], tables.rules, tables.modes,
                       use_kernel=True)
    mr, _ = mops.match(words[:128], tables.rules, tables.modes,
                       use_kernel=False)
    ok = np.array_equal(np.asarray(mk), np.asarray(mr))
    row("kernel_matcher_1024pkt", t * 1e6,
        f"mpps={1024 / t / 1e6:.1f};pallas_interpret_ok={ok}")


if __name__ == "__main__":
    run()
