"""Paper Fig 10 *end-to-end*: MPI datatype receive offload measured through
the lossy multi-node fabric, plus collective goodput vs node count.

Overlap methodology (paper §V-C): the receiver posts ``irecv`` for a typed
message, then runs a host computation sized — as in the paper — "slightly
longer than the data transfer" (1.25× the calibrated lossless transfer
time), then polls for completion.  The NIC unpacks every payload byte
through the committed index map while the host computes, so

    R = T_MM / (T_MM + T_Poll),   T_Poll = max(0, T_xfer − T_MM)

Times are *modeled* fabric ticks mapped to wall time via the same
``TICK_NS`` calibration bench_fabric uses (4-tick RTT = 30 us), so the
numbers live in the paper's 100G setting, not this host's speed.  At
loss=0 the transfer hides completely (R ≈ 1); loss makes retransmission
tails poke out of the compute window — the curve the paper cannot show.

A host-unpack baseline row (the same gather run with numpy on the host
after a raw transfer) quantifies what the offload removes from T_Poll.

Writes every point to ``BENCH_mpi.json``.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import List, Optional

import numpy as np

from benchmarks.common import row, timed_scenario
from repro import mpi
from repro.core import ddt as ddtlib
from repro.net import LinkConfig

LOSSES = [0.0, 0.02, 0.05]
ITERS = 4
TICK_NS = 7_500.0                       # 4-tick RTT == 30 us (bench_fabric)
MM_FACTOR = 1.25                        # compute = 1.25 x lossless transfer
NODE_COUNTS = [2, 4, 8]
COLLECTIVE_BYTES = 1 << 13              # per-rank payload for goodput rows
JSON_PATH = "BENCH_mpi.json"

# ---- large-message fast path sweep (per-rank vector sizes) ----
LARGE_SIZES = [256 << 10, 1 << 20, 4 << 20]
LARGE_LOSSES = [0.0, 0.02]
LARGE_RANKS = 8


def _large_cfg() -> mpi.MpiConfig:
    """Wire sized for multi-MiB vectors: big frames, deep SLMP windows,
    wide NIC ingress batches, 128 KiB rendezvous segments, 8 slot
    credits — the configuration the gradient-sync numbers are quoted at."""
    return mpi.MpiConfig(batch=32, slmp_window=64, mtu_payload=1408,
                         n_rdv_slots=8, coll_seg_bytes=128 << 10)


def _dtypes():
    reg = mpi.DatatypeRegistry()
    return reg, dict(
        simple=reg.register(ddtlib.simple_ddt(), count=1024, name="simple"),
        complex=reg.register(ddtlib.complex_ddt(), count=512,
                             name="complex"),
    )


def _one_transfer(comm: mpi.Communicator, cid: int, mem, buf,
                  max_ticks=200_000) -> int:
    """Ticks from posting irecv+isend to receive completion."""
    t0 = comm.now
    r = comm.irecv(1, buf, source=0, tag=1)
    s = comm.isend(0, 1, mem, tag=1, datatype=cid)
    comm.wait(r, s, max_ticks=max_ticks)
    return comm.now - t0


def _overlap_sweep(records: List[dict]) -> None:
    reg, ids = _dtypes()
    comm = mpi.Communicator(2, registry=reg, seed=0)
    rng = np.random.default_rng(0)
    for name, cid in ids.items():
        c = reg.committed(cid)
        mem = rng.integers(0, 256, c.mem_bytes).astype(np.uint8)
        buf = np.zeros(c.mem_bytes, np.uint8)
        # calibrate T_MM on a lossless wire (the paper sizes its matmul
        # against the undisturbed transfer)
        comm.rewire(link_cfg=LinkConfig(loss=0.0, latency=2, jitter=2),
                    seed=1)
        t_xfer0 = _one_transfer(comm, cid, mem, buf)
        t_mm = int(np.ceil(MM_FACTOR * t_xfer0))
        # host-unpack baseline: what T_Poll would additionally carry if the
        # host ran the gather (numpy dataloop) instead of the NIC
        msg = ddtlib.pack_np(c, mem)
        host_dst = np.zeros(c.mem_bytes, np.uint8)
        t0 = time.perf_counter()
        for _ in range(5):
            ddtlib.unpack_np(c, msg, host_dst)
        host_unpack_us = (time.perf_counter() - t0) / 5 * 1e6
        for loss in LOSSES:
            comm.rewire(link_cfg=LinkConfig(loss=loss, latency=2, jitter=2),
                        seed=7)
            ratios, xfers, retx = [], [], 0
            for it in range(ITERS):
                buf[:] = 0
                t_xfer = _one_transfer(comm, cid, mem, buf)
                t_poll = max(0, t_xfer - t_mm)
                ratios.append(t_mm / (t_mm + t_poll))
                xfers.append(t_xfer)
            retx = comm.stats()[0]["retransmits"]
            r_mean = float(np.mean(ratios))
            gbps = c.msg_bytes * 8 / (np.mean(xfers) * TICK_NS)
            rec = dict(kind="mpi_overlap", datatype=name, loss=loss,
                       msg_bytes=c.msg_bytes, mem_bytes=c.mem_bytes,
                       t_mm_ticks=t_mm, t_xfer_ticks=float(np.mean(xfers)),
                       overlap_ratio=round(r_mean, 4),
                       goodput_gbps=round(float(gbps), 3),
                       retransmits=retx,
                       host_unpack_us=round(host_unpack_us, 1))
            records.append(rec)
            row(f"mpi_overlap_{name}_loss{int(loss * 100)}",
                np.mean(xfers) * TICK_NS / 1e3,
                f"R={r_mean:.4f};gbps={gbps:.2f};retx={retx};"
                f"host_unpack_us={host_unpack_us:.0f}")


def _collective_sweep(records: List[dict]) -> None:
    """Collective goodput vs node count, with the algorithm schedule
    recorded per point: recursive-doubling/Bruck (log₂ n rounds) against
    the linear/pairwise baselines (n−1 rounds) — the message-count win
    PsPIN predicts dominates at scale."""
    rng = np.random.default_rng(2)
    for n in NODE_COUNTS:
        comm = mpi.Communicator(n, seed=3,
                                link_cfg=LinkConfig(loss=0.02, latency=2,
                                                    jitter=2))
        vals = [rng.normal(size=COLLECTIVE_BYTES // 8) for _ in range(n)]
        ref = np.sum(vals, axis=0)
        mats = [rng.integers(0, 256, (n, COLLECTIVE_BYTES // n))
                .astype(np.uint8) for _ in range(n)]

        runs = []
        for alg in ("rd", "linear"):
            t0 = comm.now
            h = mpi.iallreduce(comm, vals, op=np.add, algorithm=alg)
            comm.wait(h, max_ticks=400_000)
            assert all(np.allclose(o, ref) for o in h.result)
            runs.append(("allreduce", h, comm.now - t0))
        for alg in ("bruck", "pairwise"):
            t0 = comm.now
            h = mpi.ialltoall(comm, mats, algorithm=alg)
            comm.wait(h, max_ticks=400_000)
            assert all((h.result[r][i] == mats[i][r]).all()
                       for r in range(n) for i in range(n))
            runs.append(("alltoall", h, comm.now - t0))

        for kind, h, ticks in runs:
            total_bytes = n * COLLECTIVE_BYTES
            gbps = total_bytes * 8 / (ticks * TICK_NS)
            rec = dict(kind=f"mpi_{kind}", n_ranks=n,
                       bytes_per_rank=COLLECTIVE_BYTES, ticks=ticks,
                       algorithm=h.algorithm, rounds=h.rounds,
                       msgs_total=h.msgs_total,
                       goodput_gbps=round(float(gbps), 3))
            records.append(rec)
            row(f"mpi_{kind}_{h.algorithm}_n{n}", ticks * TICK_NS / 1e3,
                f"gbps={gbps:.2f};ticks={ticks};rounds={h.rounds};"
                f"msgs={h.msgs_total}")
        by_alg = {h.algorithm: h for _, h, _ in runs}
        assert by_alg["allreduce_rd"].rounds \
            <= by_alg["allreduce_linear"].rounds
        if n & (n - 1) == 0 and n > 2:
            # the headline criterion: log₂N vs N−1 rounds at 8 ranks
            assert by_alg["allreduce_rd"].rounds \
                < by_alg["allreduce_linear"].rounds


def _overlap_nonblocking(records: List[dict]) -> None:
    """Post ``iallreduce``, spin host compute while the plan progresses
    under the compute window, then poll: R = T_MM / (T_MM + T_Poll), the
    §V-C overlap methodology applied to a whole collective instead of a
    single typed receive.  Records the algorithm the size selector chose
    for every point."""
    n = 4
    comm = mpi.Communicator(n, seed=5,
                            link_cfg=LinkConfig(loss=0.0, latency=2,
                                                jitter=2))
    rng = np.random.default_rng(9)
    for nbytes, forced in ((4 << 10, None), (24 << 10, None),
                           (24 << 10, "tree")):
        vals = [rng.normal(size=nbytes // 8) for _ in range(n)]
        ref = np.sum(vals, axis=0)
        alg = forced or "auto"
        # calibrate: lossless completion time of this collective
        comm.rewire(link_cfg=LinkConfig(loss=0.0, latency=2, jitter=2),
                    seed=11)
        t0 = comm.now
        h = mpi.iallreduce(comm, vals, algorithm=alg)
        comm.wait(h, max_ticks=400_000)
        t_xfer0 = comm.now - t0
        t_mm = int(np.ceil(MM_FACTOR * t_xfer0))
        for loss in LOSSES:
            comm.rewire(link_cfg=LinkConfig(loss=loss, latency=2,
                                            jitter=2), seed=13)
            ratios = []
            for _ in range(ITERS):
                h = mpi.iallreduce(comm, vals, algorithm=alg)
                comm.progress(t_mm)           # the host compute window
                t0 = comm.now
                comm.wait(h, max_ticks=400_000)
                t_poll = comm.now - t0        # what compute failed to hide
                ratios.append(t_mm / (t_mm + t_poll))
                assert all(np.allclose(o, ref) for o in h.result)
            r_mean = float(np.mean(ratios))
            rec = dict(kind="mpi_overlap_nonblocking", n_ranks=n,
                       bytes_per_rank=nbytes, loss=loss,
                       algorithm=h.algorithm, rounds=h.rounds,
                       msgs_total=h.msgs_total, t_mm_ticks=t_mm,
                       overlap_ratio=round(r_mean, 4))
            records.append(rec)
            row(f"mpi_overlap_nb_{h.algorithm}_{nbytes >> 10}k"
                f"_loss{int(loss * 100)}",
                t_mm * TICK_NS / 1e3,
                f"R={r_mean:.4f};rounds={h.rounds}")


def _allreduce_large_sweep(records: List[dict]) -> None:
    """The large-message fast path head-to-head: Rabenseifner (the auto
    pick at these sizes) vs recursive doubling, 8 ranks, 256 KiB–4 MiB
    per rank, lossless and 2% loss.  Every point records the schedule
    metadata (rounds / msgs / bytes-on-wire) so the win is attributable:
    rd ships ⌈log₂ n⌉ full vectors per rank where Rabenseifner ships
    ~2·(n−1)/n of one — and at the largest size that bandwidth gap must
    show up in modeled ticks too (asserted)."""
    comm = mpi.Communicator(LARGE_RANKS, seed=0, cfg=_large_cfg(),
                            link_cfg=LinkConfig(latency=1))
    rng = np.random.default_rng(21)
    for loss in LARGE_LOSSES:
        for nbytes in LARGE_SIZES:
            vals = [rng.integers(0, 1 << 20, nbytes // 8).astype(np.int64)
                    for _ in range(LARGE_RANKS)]
            ref = np.sum(np.stack(vals), axis=0)
            by_alg = {}
            for alg in ("rd", "auto"):
                comm.rewire(link_cfg=LinkConfig(loss=loss, latency=1),
                            seed=31)
                t0 = comm.now
                h = mpi.iallreduce(comm, vals, algorithm=alg)
                comm.wait(h, max_ticks=4_000_000)
                ticks = comm.now - t0
                assert all((o == ref).all() for o in h.result)
                stalls = sum(e["credit_stalls"] for e in comm.stats())
                gbps = nbytes * LARGE_RANKS * 8 / (ticks * TICK_NS)
                rec = dict(kind="allreduce_sweep", n_ranks=LARGE_RANKS,
                           bytes_per_rank=nbytes, loss=loss,
                           requested=alg, algorithm=h.algorithm,
                           rounds=h.rounds, msgs_total=h.msgs_total,
                           bytes_wire=h.bytes_wire, ticks=ticks,
                           credit_stalls=stalls,
                           goodput_gbps=round(float(gbps), 3))
                records.append(rec)
                by_alg[h.algorithm] = rec
                row(f"allreduce_{h.algorithm}_{nbytes >> 10}k"
                    f"_loss{int(loss * 100)}", ticks * TICK_NS / 1e3,
                    f"gbps={gbps:.2f};wireMB={h.bytes_wire / 2**20:.1f};"
                    f"rounds={h.rounds};msgs={h.msgs_total};"
                    f"stalls={stalls}")
            assert "allreduce_rab" in by_alg, \
                "auto must select Rabenseifner at large sizes"
            rab, rd = by_alg["allreduce_rab"], by_alg["allreduce_rd"]
            assert rab["bytes_wire"] < rd["bytes_wire"], (rab, rd)
            if nbytes == max(LARGE_SIZES):
                assert rab["ticks"] < rd["ticks"], (rab, rd)


def _grad_allreduce(records: List[dict]) -> None:
    """The trainer's gradient sync end-to-end: a ≥4 MiB gradient pytree
    per shard, reduced through :class:`repro.train.manual_dp.FabricGradSync`
    (nonblocking Rabenseifner over the fabric) with the progress hook
    driven from inside a modeled backprop window 1.25x the lossless
    transfer — §V-C overlap methodology applied to the data-parallel
    step.  At loss=0 the transfer must hide almost completely."""
    from repro.train.manual_dp import FabricGradSync
    n = 4
    comm = mpi.Communicator(n, seed=7, cfg=_large_cfg(),
                            link_cfg=LinkConfig(latency=1))
    rng = np.random.default_rng(33)
    # a transformer-block-shaped gradient pytree, ~4.25 MiB of f32
    shapes = dict(wq=(1024, 256), wk=(1024, 256), wv=(1024, 256),
                  wo=(256, 1024), w_up=(256, 1024), w_down=(1024, 192),
                  embed=(4096, 24), norm=(1024,))
    grads = [{k: rng.normal(size=s).astype(np.float32)
              for k, s in shapes.items()} for _ in range(n)]
    ref_mean = {k: np.mean(np.stack([g[k] for g in grads]), axis=0,
                           dtype=np.float64).astype(np.float32)
                for k in shapes}
    sync = FabricGradSync(comm)
    # calibrate: lossless completion with no compute overlap
    sync.post([{k: g[k].copy() for k in g} for g in grads])
    sync.wait()
    t_xfer0 = sync.last_stats["total_ticks"]
    t_mm = int(np.ceil(MM_FACTOR * t_xfer0))
    for loss in LARGE_LOSSES:
        comm.rewire(link_cfg=LinkConfig(loss=loss, latency=1), seed=13)
        sync.post([{k: g[k].copy() for k in g} for g in grads])
        left = t_mm
        while left > 0:                 # the backprop progress hook
            sync.progress(min(64, left))
            left -= 64
        means = sync.wait()
        st = sync.last_stats
        for m in means:
            for k in shapes:
                np.testing.assert_allclose(m[k], ref_mean[k], rtol=1e-5,
                                           atol=1e-6)
        gbps = st["grad_bytes"] * 8 / (st["total_ticks"] * TICK_NS)
        rec = dict(kind="grad_allreduce", n_ranks=n, loss=loss,
                   grad_bytes=st["grad_bytes"],
                   algorithm=st["algorithm"], rounds=st["rounds"],
                   msgs_total=st["msgs_total"],
                   bytes_wire=st["bytes_wire"], t_mm_ticks=t_mm,
                   poll_ticks=st["poll_ticks"],
                   overlap_ratio=round(st["overlap_ratio"], 4),
                   goodput_gbps=round(float(gbps), 3))
        records.append(rec)
        row(f"grad_allreduce_loss{int(loss * 100)}",
            st["total_ticks"] * TICK_NS / 1e3,
            f"R={st['overlap_ratio']:.4f};gbps={gbps:.2f};"
            f"alg={st['algorithm']}")
        if loss == 0.0:
            assert st["overlap_ratio"] >= 0.9, st


SCENARIOS = [
    ("overlap", _overlap_sweep),
    ("collective", _collective_sweep),
    ("overlap_nonblocking", _overlap_nonblocking),
    ("allreduce_large", _allreduce_large_sweep),
    ("grad_allreduce", _grad_allreduce),
]


def run(json_path: Optional[str] = JSON_PATH,
        scenario_filter: Optional[str] = None) -> List[dict]:
    records: List[dict] = []
    selected = [(n, fn) for n, fn in SCENARIOS
                if not scenario_filter or scenario_filter in n]
    if not selected:
        sys.exit(f"no bench_mpi scenario matches {scenario_filter!r}; "
                 f"available: " + ", ".join(n for n, _ in SCENARIOS))
    for name, fn in selected:
        timed_scenario(name, fn, records)
    if json_path:
        payload = dict(bench="mpi", tick_ns=TICK_NS, mm_factor=MM_FACTOR,
                       records=records)
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        row("mpi_json", 0.0, f"wrote={os.path.abspath(json_path)};"
            f"points={len(records)}")
    return records


if __name__ == "__main__":
    run(scenario_filter=sys.argv[1] if len(sys.argv) > 1 else None)
