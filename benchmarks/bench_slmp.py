"""Paper Fig 8: SLMP file-transfer throughput vs window size, with failed
transfers at over-aggressive windows.

Discrete-time simulation driven by the *real* receiver (the full NIC
pipeline with SLMP handlers): each tick the sender injects up to
``window`` segments; the receiver drains at its processing rate
(HPU-bound, from the hardware model); segments that find the large-slot
FIFO exhausted are dropped (alloc underflow — exactly the paper's failure
mode).  A transfer fails if any segment is lost (message-level mode).
Goodput uses modeled wire/processing time, so the numbers reproduce the
100 Gbps loopback setting rather than this host's speed.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import apps, hwmodel, packet as pkt, slmp
from repro.net import Fabric, LinkConfig, Node, SlmpSenderEngine

WINDOWS = [1, 4, 16, 64, 170, 256]
FILE_SIZES = [1 << 16, 1 << 20]          # 64 KiB, 1 MiB
RECV_RATE = 12                           # segments the HPUs drain per tick
QUEUE_CAP = 170                          # large-slot FIFO depth (Table I)


def simulate_transfer(msg: np.ndarray, window: int):
    """Window-mode sender: push `window` segments back-to-back, wait for
    the window's ACKs (receiver fully drains during the wait).  Segments
    beyond the large-slot FIFO depth find no buffer -> alloc underflow
    drop (the paper's failure mode at windows > 170).

    Returns (time_ns, lost_segments, n_segments)."""
    cfg = slmp.SlmpSenderConfig(window=window, mtu_payload=1024,
                                syn_every_packet=False)
    frames = slmp.segment_message(msg, 1, cfg)
    n = len(frames)
    seg_wire = hwmodel.wire_ns(1024 + 52)
    proc_ns = 2_600                  # ingress DMA + handler + host DMA
    rtt_ns = 30_000
    sent = lost = 0
    t_ns = 0.0
    while sent < n:
        burst = min(window, n - sent)
        # arrivals outpace the HPUs: occupancy peaks near the full burst
        lost += max(0, burst - QUEUE_CAP)
        # window round: bounded by receiver processing, then ACK wait
        t_ns += max(burst * seg_wire, burst * proc_ns) + rtt_ns
        sent += burst
    return t_ns, lost, n


def run() -> None:
    rng = np.random.default_rng(0)
    # functional check end-to-end over the two-node fabric, with real loss:
    # the retransmission path must recover a 50 KB transfer at 10% drops
    msg = rng.integers(0, 256, 50_000).astype(np.uint8)
    sender = SlmpSenderEngine(msg, 3, slmp.SlmpSenderConfig(
        window=8, timeout=10, src_mac=pkt.node_mac(0),
        dst_mac=pkt.node_mac(1)))
    tx = Node("tx", pkt.node_mac(0), [apps.make_null_context()],
              engines=[sender], batch=16)
    rx = Node("rx", pkt.node_mac(1), [slmp.make_slmp_context()],
              host_bytes=1 << 17, batch=16)
    fab = Fabric([tx, rx], link_cfg=LinkConfig(loss=0.1, latency=2,
                                               jitter=2), seed=1)
    ticks = fab.run(max_ticks=20_000)
    okay = sender.done and bool((rx.read_host(0, len(msg)) == msg).all())
    row("slmp_functional_50KB_loss10", 0.0,
        f"delivered={okay};ticks={ticks};"
        f"retx={sender.sender.retransmits}")

    for size in FILE_SIZES:
        msg = rng.integers(0, 256, size).astype(np.uint8)
        for w in WINDOWS:
            t_ns, lost, nseg = simulate_transfer(msg, w)
            gbps = size * 8 / t_ns
            m_gbps, m_fail = hwmodel.slmp_goodput_gbps(w)
            status = "ok" if lost == 0 else \
                f"TRANSFER-FAILED(lost={lost}/{nseg})"
            row(f"slmp_w{w}_{size >> 10}KB", t_ns / 1e3,
                f"gbps={gbps:.2f};model_gbps={m_gbps:.2f};"
                f"model_fail_p={m_fail:.2f};{status}")

    # iperf-style baseline: raw wire rate, no handler processing
    seg_ns = hwmodel.wire_ns(1024 + 52)
    row("slmp_iperf_baseline", 0.0,
        f"gbps={1024 * 8 / seg_ns:.2f}")


if __name__ == "__main__":
    run()
