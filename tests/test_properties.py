"""Hypothesis property tests on system invariants: allocator conservation,
DDT pack/unpack laws, SLMP reassembly, checksum algebra, matcher
consistency."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import alloc as palloc
from repro.core import ddt as ddtlib
from repro.core import packet as pkt
from repro.core import slmp
from repro.kernels.ddt import ops as dops

SET = dict(max_examples=25, deadline=None)


# ----------------------------------------------------------- allocator
@settings(**SET)
@given(st.lists(st.tuples(st.integers(1, 1536), st.booleans()),
                min_size=1, max_size=32),
       st.integers(2, 16), st.integers(2, 8))
def test_allocator_invariants(reqs, n_small, n_large):
    """(1) never double-allocates a live slot; (2) free+alloc conserves
    capacity; (3) addresses stay in their class region."""
    state = palloc.make_state(n_small=n_small, n_large=n_large)
    live = set()
    for chunk_start in range(0, len(reqs), 8):
        chunk = reqs[chunk_start:chunk_start + 8]
        sizes = jnp.asarray([r[0] for r in chunk], jnp.int32)
        valid = jnp.asarray([True] * len(chunk))
        state, addr, ok = palloc.alloc(state, sizes, valid)
        addr = np.asarray(addr)
        ok = np.asarray(ok)
        freed = []
        for i, (size, keep) in enumerate(chunk):
            if not ok[i]:
                continue
            a = int(addr[i])
            assert a not in live, "double allocation"
            if size <= pkt.SMALL_SLOT:
                assert 0 <= a < n_small * pkt.SMALL_SLOT
            else:
                assert palloc.LARGE_BASE <= a
            live.add(a)
            if not keep:
                freed.append(a)
        if freed:
            fa = jnp.asarray(freed + [0] * (8 - len(freed)), jnp.int32)
            do = jnp.asarray([True] * len(freed) + [False] * (8 - len(freed)))
            state = palloc.free(state, fa, do)
            live -= set(freed)
    # conservation: live slots + free count == capacity per class
    small_live = sum(1 for a in live if a < palloc.LARGE_BASE)
    large_live = len(live) - small_live
    assert int(state.small_count) == n_small - small_live
    assert int(state.large_count) == n_large - large_live


# ------------------------------------------------------------------ DDT
ddt_strategy = st.builds(
    ddtlib.Vector,
    count=st.integers(1, 6), blocklen=st.integers(1, 4),
    stride=st.integers(1, 8), base=st.just(ddtlib.MPI_FLOAT),
)


@settings(**SET)
@given(ddt_strategy, st.integers(1, 3))
def test_ddt_pack_unpack_identity(d, count):
    """unpack(pack(mem)) restores every byte the datatype touches."""
    c = ddtlib.commit(d, count)
    rng = np.random.default_rng(0)
    mem = rng.integers(0, 256, max(c.mem_bytes, 1)).astype(np.uint8)
    msg = ddtlib.pack_np(c, mem)
    assert len(msg) == c.msg_bytes == d.size * count
    out = ddtlib.unpack_np(c, msg, np.zeros_like(mem))
    mask = c.mem_to_msg >= 0
    np.testing.assert_array_equal(out[mask], mem[mask])
    # untouched bytes stay zero (holes preserved)
    assert (out[~mask] == 0).all()


@settings(**SET)
@given(ddt_strategy, st.integers(1, 2))
def test_ddt_kernel_equals_numpy_pack(d, count):
    c = ddtlib.commit(d, count)
    try:
        pack_idx, unpack_idx = ddtlib.element_maps(c, 4)
    except ValueError:
        return                                     # not element-aligned
    rng = np.random.default_rng(1)
    mem = rng.normal(size=c.mem_bytes // 4).astype(np.float32)
    msg_np = ddtlib.pack_np(c, mem.view(np.uint8))
    msg_k = dops.pack(jnp.asarray(mem), jnp.asarray(pack_idx),
                      use_kernel=True)
    np.testing.assert_array_equal(np.asarray(msg_k).view(np.uint8), msg_np)


@settings(**SET)
@given(st.integers(1, 5), st.integers(1, 6), st.integers(0, 6),
       st.integers(1, 2), st.integers(0, 2**31 - 1))
def test_ddt_overlapping_unpack_last_occurrence_wins(count, blocklen,
                                                     stride, n, seed):
    """When stride < blocklen the layout overlaps itself; MPI unpack
    applies message bytes in serialization order, so the *last* occurrence
    of each memory byte wins.  Also checks the deduplicated ("winner-only")
    map the repro.mpi registry uploads to the NIC: applying only winner
    bytes — in ANY order — must give the same result, which is what makes
    the offloaded unpack immune to segment reordering/retransmission."""
    d = ddtlib.Vector(count, blocklen, stride, ddtlib.MPI_BYTE)
    c = ddtlib.commit(d, n)
    rng = np.random.default_rng(seed)
    msg = rng.integers(0, 256, c.msg_bytes).astype(np.uint8)
    mem0 = np.full(max(c.mem_bytes, 1), 0x55, np.uint8)[:c.mem_bytes]
    out = ddtlib.unpack_np(c, msg, mem0.copy())
    # sequential byte-by-byte oracle
    ref = mem0.copy()
    for k in range(c.msg_bytes):
        ref[c.msg_to_mem[k]] = msg[k]
    np.testing.assert_array_equal(out, ref)
    # winner-only map, applied in a random order
    winner = c.mem_to_msg[c.msg_to_mem] == np.arange(c.msg_bytes)
    ref2 = mem0.copy()
    for k in rng.permutation(c.msg_bytes):
        if winner[k]:
            ref2[c.msg_to_mem[k]] = msg[k]
    np.testing.assert_array_equal(ref2, out)
    # every touched memory byte has exactly one winner
    assert int(winner.sum()) == int((c.mem_to_msg >= 0).sum())


@settings(**SET)
@given(st.integers(0, 4), st.integers(0, 4), st.integers(0, 5),
       st.integers(1, 3))
def test_ddt_degenerate_vectors_commit_to_empty_maps(count, blocklen,
                                                     stride, n):
    """Zero-count / zero-blocklen constructors are legal MPI: they must
    commit to empty index maps (no crash), pack to zero bytes, and unpack
    as a no-op."""
    zeros = [ddtlib.Vector(0, blocklen, stride, ddtlib.MPI_FLOAT),
             ddtlib.Vector(count, 0, stride, ddtlib.MPI_FLOAT),
             ddtlib.HVector(0, blocklen, 4 * stride, ddtlib.MPI_FLOAT),
             ddtlib.HVector(count, 0, 4 * stride, ddtlib.MPI_FLOAT)]
    for d in zeros:
        c = ddtlib.commit(d, n)
        assert c.msg_bytes == 0 and c.msg_to_mem.size == 0
        assert d.size == 0
        assert (c.mem_to_msg == -1).all()
        mem = (np.arange(max(c.mem_bytes, 4)) % 256).astype(
            np.uint8)[:c.mem_bytes]
        assert ddtlib.pack_np(c, mem).size == 0
        np.testing.assert_array_equal(
            ddtlib.unpack_np(c, np.zeros(0, np.uint8), mem.copy()), mem)


@settings(**SET)
@given(st.integers(1, 5000), st.integers(1, 1400), st.integers(0, 2**28))
def test_slmp_segmentation_covers_message(nbytes, payload, msg_id):
    msg = np.random.default_rng(nbytes).integers(
        0, 256, nbytes).astype(np.uint8)
    cfg = slmp.SlmpSenderConfig(window=4, mtu_payload=payload)
    frames = slmp.segment_message(msg, msg_id, cfg)
    # offsets tile the message exactly, exactly one EOM (the last)
    seen = np.zeros(nbytes, bool)
    eoms = 0
    for f in frames:
        fj = jnp.asarray(f)
        off = int(pkt.read_u32(fj, pkt.SLMP_OFFSET))
        ln = len(f) - pkt.SLMP_PAYLOAD
        flags = int(pkt.read_u16(fj, pkt.SLMP_FLAGS))
        seen[off:off + ln] = True
        np.testing.assert_array_equal(f[pkt.SLMP_PAYLOAD:],
                                      msg[off:off + ln])
        if flags & pkt.SLMP_FLAG_EOM:
            eoms += 1
            assert f is frames[-1]
    assert seen.all()
    assert eoms == 1


# ------------------------------------------------------------- checksum
@settings(**SET)
@given(st.binary(min_size=0, max_size=1200))
def test_checksum_rfc1071_properties(data):
    """Inserting the computed checksum makes the total sum verify (the
    defining property of the internet checksum)."""
    buf = np.frombuffer(data, np.uint8)
    c = pkt.internet_checksum_np(buf)
    with_ck = np.concatenate(
        [buf if len(buf) % 2 == 0 else np.concatenate(
            [buf, np.zeros(1, np.uint8)]),
         np.asarray([(c >> 8) & 0xFF, c & 0xFF], np.uint8)])
    assert pkt.internet_checksum_np(with_ck) == 0


# -------------------------------------------- log-step MPI collectives
# One lossy Communicator per rank count, built lazily and rewired per
# example (the jitted NIC datapath compiles once per n).
_MPI_COMMS = {}


def _mpi_comm(n):
    from repro import mpi
    from repro.net import LinkConfig
    if n not in _MPI_COMMS:
        _MPI_COMMS[n] = mpi.Communicator(
            n, seed=0, link_cfg=LinkConfig(loss=0.02, latency=1, jitter=1))
    return _MPI_COMMS[n]


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([2, 3, 4, 5]), st.integers(1, 48),
       st.sampled_from(["int64", "int32", "uint8"]),
       st.integers(0, 2**31 - 1))
def test_rd_allreduce_agrees_with_linear(n, count, dtype, seed):
    """Recursive-doubling allreduce (including the non-power-of-two fold)
    computes exactly what the naive linear gather+fan-out computes, for
    any rank count, payload size, and integer dtype (exact ops — the
    combine order cannot hide behind rounding)."""
    from repro import mpi
    from repro.net import LinkConfig
    comm = _mpi_comm(n)
    rng = np.random.default_rng(seed)
    vals = [rng.integers(0, 1 << 20, count).astype(dtype)
            for _ in range(n)]
    comm.rewire(link_cfg=LinkConfig(loss=0.02, latency=1, jitter=1),
                seed=seed % 1000)
    rd = mpi.allreduce(comm, vals, algorithm="rd", max_ticks=400_000)
    comm.rewire(link_cfg=LinkConfig(loss=0.02, latency=1, jitter=1),
                seed=seed % 1000)
    lin = mpi.allreduce(comm, vals, algorithm="linear",
                        max_ticks=400_000)
    ref = np.sum(np.stack(vals).astype(np.int64), axis=0).astype(dtype)
    for a, b in zip(rd, lin):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, ref)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([2, 3, 4, 5]), st.integers(0, 6),
       st.integers(1, 32), st.integers(0, 2**31 - 1))
def test_bruck_alltoallv_agrees_with_pairwise(n, size_spread, unit, seed):
    """Bruck's ⌈log₂ n⌉-round store-and-forward exchange delivers exactly
    the blocks the naive pairwise exchange delivers — for any rank count
    (powers of two or not) and variable per-pair block sizes, including
    zero-size blocks."""
    from repro import mpi
    from repro.net import LinkConfig
    comm = _mpi_comm(n)
    rng = np.random.default_rng(seed)
    blocks = [[rng.integers(0, 256,
                            int(rng.integers(0, size_spread + 1)) * unit)
               .astype(np.uint8) for _ in range(n)] for _ in range(n)]
    comm.rewire(link_cfg=LinkConfig(loss=0.02, latency=1, jitter=1),
                seed=seed % 1000)
    br = mpi.alltoallv(comm, blocks, algorithm="bruck",
                       max_ticks=400_000)
    comm.rewire(link_cfg=LinkConfig(loss=0.02, latency=1, jitter=1),
                seed=seed % 1000)
    pw = mpi.alltoallv(comm, blocks, algorithm="pairwise",
                       max_ticks=400_000)
    for r in range(n):
        for i in range(n):
            np.testing.assert_array_equal(br[r][i], pw[r][i])
            np.testing.assert_array_equal(br[r][i], blocks[i][r])


# ------------------------------------ segmented large-message collectives
# Tiny segments (2 KiB chunks, 4 KiB eager slots) make the rendezvous
# fast path trigger at property-test sizes, so these exercise the same
# segmentation/credit machinery the multi-MiB gradient sweep uses.
_SEG_COMMS = {}


def _seg_comm(n):
    from repro import mpi
    from repro.net import LinkConfig
    if n not in _SEG_COMMS:
        cfg = mpi.MpiConfig(eager_threshold=1024, eager_slot_bytes=4096,
                            coll_seg_bytes=2048, n_rdv_slots=4)
        _SEG_COMMS[n] = mpi.Communicator(
            n, seed=0, cfg=cfg,
            link_cfg=LinkConfig(loss=0.05, latency=1, jitter=1))
    return _SEG_COMMS[n]


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([2, 3, 4, 5]), st.integers(1, 2000),
       st.sampled_from(["int64", "int32", "uint8"]),
       st.integers(0, 2**31 - 1))
def test_rabenseifner_allreduce_agrees_with_linear(n, count, dtype, seed):
    """Rabenseifner (reduce-scatter + allgather over segmented rendezvous
    chunks, non-power-of-two fold included) computes exactly what the
    naive linear gather+fan-out computes, for any rank count, vector
    length (empty halving ranges included), and integer dtype, on a 5%
    lossy wire."""
    from repro import mpi
    from repro.net import LinkConfig
    comm = _seg_comm(n)
    rng = np.random.default_rng(seed)
    vals = [rng.integers(0, 1 << 20, count).astype(dtype)
            for _ in range(n)]
    link = LinkConfig(loss=0.05, latency=1, jitter=1)
    comm.rewire(link_cfg=link, seed=seed % 1000)
    rab = mpi.allreduce(comm, vals, algorithm="rab", max_ticks=600_000)
    comm.rewire(link_cfg=link, seed=seed % 1000)
    lin = mpi.allreduce(comm, vals, algorithm="linear",
                        max_ticks=600_000)
    ref = np.sum(np.stack(vals).astype(np.int64), axis=0).astype(dtype)
    for a, b in zip(rab, lin):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, ref)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([2, 3, 4, 5]), st.integers(1, 16000),
       st.integers(0, 4), st.integers(0, 2**31 - 1))
def test_pipelined_bcast_agrees_with_binomial(n, nbytes, root_pick, seed):
    """The segment-streaming pipelined bcast delivers bit-identical
    buffers to the blocking binomial bcast for any payload size (1 byte
    through many segments), root, and rank count on a lossy wire."""
    from repro import mpi
    from repro.net import LinkConfig
    comm = _seg_comm(n)
    root = root_pick % n
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, nbytes).astype(np.uint8)
    link = LinkConfig(loss=0.05, latency=1, jitter=1)

    def run(algorithm):
        comm.rewire(link_cfg=link, seed=seed % 1000)
        bufs = [data.copy() if r == root else np.zeros_like(data)
                for r in range(n)]
        mpi.bcast(comm, bufs, root=root, algorithm=algorithm,
                  max_ticks=600_000)
        return bufs

    for bp, bb in zip(run("pipelined"), run("binomial")):
        np.testing.assert_array_equal(bp, data)
        np.testing.assert_array_equal(bp, bb)


# ---------------------------------------------------------------- MoE
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_moe_combine_weights_sum_to_one(seed):
    import jax
    from repro import configs
    from repro.models import moe as M
    cfg = configs.get_smoke_config("qwen2-moe-a2.7b")
    p = M.moe_init(jax.random.key(seed % 1000), cfg)
    x = jnp.asarray(np.random.default_rng(seed).normal(
        size=(2, 8, cfg.d_model)).astype(np.float32), jnp.bfloat16)
    y, aux = M.moe_apply(p, cfg, x, capacity_factor=float(cfg.n_experts))
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    assert not bool(jnp.isnan(y).any())
