"""Per-architecture smoke tests (reduced same-family configs): one
forward/train step on CPU asserting shapes + no NaNs, plus decode-path
consistency and family-specific behaviours."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs import shapes as sh
from repro.models import ssm as ssmlib
from repro.models.model import build_model
from repro.train import optimizer as opt

ARCHS = configs.ARCHS


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = configs.get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = sh.train_batch_specs(cfg, seq=32, batch=2, concrete=True,
                                 rng=rng)
    logits, aux = jax.jit(m.forward)(params, {k: v for k, v in batch.items()
                                              if k != "targets"})
    if cfg.family == "vlm":
        total = batch["img_embeds"].shape[1] + batch["tokens"].shape[1]
    else:
        total = 32
    assert logits.shape == (2, total, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    # one full train step (loss + grads + adamw)
    ost = opt.init(params)
    ocfg = opt.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)

    def step(p, o, b):
        (loss, metr), g = jax.value_and_grad(m.loss_fn, has_aux=True)(p, b)
        p2, o2, om = opt.apply_updates(p, o, g, ocfg)
        return p2, o2, loss

    p2, o2, loss = jax.jit(step)(params, ost, batch)
    assert np.isfinite(float(loss))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch, rng):
    cfg = configs.get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(1))
    s, b = 24, 2
    batch = sh.train_batch_specs(cfg, seq=s, batch=b, concrete=True,
                                 rng=rng)
    fwd = dict(batch)
    fwd.pop("targets", None)
    logits_full, _ = jax.jit(m.forward)(params, fwd)
    if cfg.family == "vlm":
        text = batch["tokens"].shape[1]
        pre = dict(fwd)
        pre["tokens"] = batch["tokens"][:, : text - 1]
        pre["positions"] = batch["positions"][:, :, : s - 1]
        tok_next = batch["tokens"][:, text - 1: text]
    else:
        pre = {k: (v[:, : s - 1] if k == "tokens" else v)
               for k, v in fwd.items()}
        tok_next = batch["tokens"][:, s - 1: s]
    _, cache = jax.jit(lambda p, bb: m.prefill(p, bb, max_len=s + 4))(
        params, pre)
    logits_dec, cache2 = jax.jit(
        lambda p, t, c: m.decode_step(p, t, c,
                                      jnp.asarray(s - 1, jnp.int32)))(
        params, tok_next, cache)
    ref = logits_full[:, -1]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    err = float(jnp.max(jnp.abs(logits_dec - ref))) / scale
    assert err < 0.05, f"{arch}: decode/forward relative error {err}"


def test_gemma3_local_global_pattern():
    cfg = configs.get_smoke_config("gemma3-1b")
    m = build_model(cfg)
    kinds = cfg.pattern_layers
    assert kinds.count("attn") * 2 < len(kinds)       # mostly local
    assert m.tail_kinds() == ("local", "local")


def test_kimi_first_layer_dense():
    cfg = configs.get_smoke_config("kimi-k2-1t-a32b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    assert len(params["head_blocks"]) == 1
    assert "mlp" in params["head_blocks"][0]          # dense, not moe
    assert "moe" in jax.tree.leaves(
        params["scan_blocks"][0], is_leaf=lambda x: isinstance(x, dict)
    )[0] or "moe" in params["scan_blocks"][0]


def test_mamba2_ssd_matches_sequential_recurrence():
    """Chunked SSD == naive sequential state recurrence."""
    b, s, h, p, n = 2, 24, 3, 4, 8
    rng = np.random.default_rng(0)
    xh = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, h))).astype(np.float32)
                     * 0.1)
    a = -jnp.asarray(np.linspace(0.5, 2.0, h).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    y, final = ssmlib.ssd_chunked(xh, dt, a, bm, cm, chunk=8)

    # naive recurrence
    st = np.zeros((b, h, n, p), np.float32)
    ys = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(a))       # (b,h)
        upd = np.einsum("bn,bh,bhp->bhnp", np.asarray(bm[:, t]),
                        np.asarray(dt[:, t]), np.asarray(xh[:, t]))
        st = st * dec[..., None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(cm[:, t]), st)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), st, rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_sequential():
    from repro.models import rglru as rg
    cfg = configs.get_smoke_config("recurrentgemma-9b")
    p = rg.rglru_init(jax.random.key(0), cfg)
    b, s = 2, 16
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(b, s, cfg.d_model)).astype(np.float32), jnp.bfloat16)
    y, state = rg.rglru_apply_train(p, cfg, x, return_state=True)
    # sequential decode over the same tokens
    cache = rg.rglru_decode_init(cfg, b, jnp.bfloat16)
    ys = []
    for t in range(s):
        yt, cache = rg.rglru_apply_decode(p, cfg, x[:, t:t + 1], cache)
        ys.append(yt)
    yseq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yseq, np.float32),
                               rtol=0.1, atol=0.05)
    np.testing.assert_allclose(np.asarray(state["h"]),
                               np.asarray(cache["h"]), rtol=2e-2,
                               atol=2e-2)


def test_sliding_window_attention_masks_far_context():
    """A local-attn token must be unaffected by tokens beyond the window."""
    from repro.models import attention as A
    cfg = configs.get_smoke_config("gemma3-1b")       # window 16
    p = A.attn_init(jax.random.key(0), cfg)
    b, s = 1, 64
    rng = np.random.default_rng(0)
    x1 = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
    x2 = jnp.asarray(np.concatenate(
        [rng.normal(size=(b, 8, cfg.d_model)),        # differs early
         np.asarray(x1[:, 8:], np.float32)], axis=1), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    y1 = A.attend_train(p, cfg, x1, pos, kind="local")
    y2 = A.attend_train(p, cfg, x2, pos, kind="local")
    # last token: window 16 -> positions < 48 irrelevant... both inputs
    # agree from position 8 on, so outputs at the end must match
    np.testing.assert_allclose(np.asarray(y1[:, -1], np.float32),
                               np.asarray(y2[:, -1], np.float32),
                               atol=1e-2)


def test_param_counts_full_configs():
    """Full configs land near their nameplate sizes."""
    expectations = {
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "qwen2-7b": (6.5e9, 8.5e9),
        "nemotron-4-15b": (14e9, 17e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "recurrentgemma-9b": (8e9, 11e9),
        "gemma3-1b": (0.9e9, 1.6e9),
        "qwen2-vl-2b": (1.5e9, 2.6e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),       # total (not active) params
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "whisper-tiny": (2e7, 8e7),
    }
    for arch, (lo, hi) in expectations.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"
