"""Large-message collective fast path: segmented Rabenseifner allreduce
and pipelined-segment tree bcast over the credit-managed rendezvous,
checkpoint round-trips mid-flight, persistent requests, and the
credit/stall observability counters.

A small-segment configuration (2 KiB chunks, 4 KiB eager slots) makes the
segmented path trigger at test-sized vectors, so these run in seconds
while exercising exactly the machinery the multi-MiB gradient sweep uses.
"""
import numpy as np
import pytest

from repro import mpi
from repro.core import apps
from repro.core import packet as pkt
from repro.net import Fabric, LinkConfig, Node

N_RANKS = 5
RNG = np.random.default_rng(4242)
LOSSY = dict(loss=0.05, latency=2, jitter=2)

SMALL_SEG_CFG = mpi.MpiConfig(eager_threshold=1024, eager_slot_bytes=4096,
                              coll_seg_bytes=2048, n_rdv_slots=4)


@pytest.fixture(scope="module")
def world():
    comm = mpi.Communicator(N_RANKS, seed=0, cfg=SMALL_SEG_CFG,
                            link_cfg=LinkConfig(**LOSSY))
    return comm


def fresh(world, seed=0, **link_kw):
    world.rewire(link_cfg=LinkConfig(**dict(LOSSY, **link_kw)), seed=seed)
    return world


# ------------------------------------------------------- segmented allreduce
def test_rabenseifner_matches_linear_and_reference(world):
    """Rabenseifner (reduce-scatter + allgather, segmented rendezvous
    transport) computes exactly what the linear baseline computes, for a
    non-power-of-two rank count and vectors far above the eager slot."""
    comm = fresh(world, seed=11)
    vals = [RNG.integers(0, 1 << 20, 4096).astype(np.int64)  # 32 KiB/rank
            for _ in range(N_RANKS)]
    ref = np.sum(np.stack(vals), axis=0)
    h = mpi.iallreduce(comm, vals, algorithm="rab")
    comm.wait(h, max_ticks=600_000)
    assert h.algorithm == "allreduce_rab"
    for o in h.result:
        np.testing.assert_array_equal(o, ref)
    comm = fresh(world, seed=11)
    lin = mpi.allreduce(comm, vals, algorithm="linear",
                        max_ticks=600_000)
    for a, b in zip(h.result, lin):
        np.testing.assert_array_equal(a, b)


def test_rabenseifner_wire_bytes_beat_rd(world):
    """The bandwidth claim the benchmark quotes: per handle metadata,
    Rabenseifner puts ~2·(n−1)/n vectors per rank on the wire where
    recursive doubling puts ⌈log₂ n⌉ full vectors."""
    comm = fresh(world, seed=13)
    vals = [RNG.integers(0, 1 << 16, 8192).astype(np.int64)
            for _ in range(N_RANKS)]
    h_rab = mpi.iallreduce(comm, vals, algorithm="rab")
    h_rd = mpi.iallreduce(comm, vals, algorithm="rd")
    comm.waitall([h_rab, h_rd], max_ticks=900_000)
    assert 0 < h_rab.bytes_wire < h_rd.bytes_wire
    for a, b in zip(h_rab.result, h_rd.result):
        np.testing.assert_array_equal(a, b)


def test_rabenseifner_tiny_vector_and_every_rank_count():
    """Vectors shorter than pof2 produce empty ranges in the halving
    schedule; every rank count from 1..6 must still reduce exactly."""
    for n in range(1, 7):
        comm = mpi.Communicator(n, seed=n, cfg=SMALL_SEG_CFG,
                                link_cfg=LinkConfig(loss=0.02, latency=1))
        vals = [RNG.integers(0, 100, 3).astype(np.int32)
                for _ in range(n)]
        ref = np.sum(np.stack(vals), axis=0)
        out = mpi.allreduce(comm, vals, algorithm="rab",
                            max_ticks=300_000)
        for o in out:
            np.testing.assert_array_equal(o, ref)


def test_pipelined_bcast_matches_binomial(world):
    """Segment-streaming bcast delivers bit-identical buffers to the
    binomial tree, for a payload spanning many segments."""
    data = RNG.integers(0, 256, 20_000).astype(np.uint8)  # ~10 segments

    def run(algorithm, seed):
        comm = fresh(world, seed=seed)
        bufs = [data.copy() if r == 1 else np.zeros_like(data)
                for r in range(N_RANKS)]
        h = mpi.ibcast(comm, bufs, root=1, algorithm=algorithm)
        comm.wait(h, max_ticks=600_000)
        return h, bufs

    h_p, bufs_p = run("pipelined", seed=17)
    assert h_p.algorithm == "bcast_pipelined"
    h_b, bufs_b = run("binomial", seed=17)
    for bp, bb in zip(bufs_p, bufs_b):
        np.testing.assert_array_equal(bp, data)
        np.testing.assert_array_equal(bp, bb)
    # the pipeline streams: rounds = depth + segments - 1, yet wire bytes
    # match the binomial tree (same tree, same payload)
    assert h_p.rounds > h_b.rounds
    assert h_p.bytes_wire >= h_b.bytes_wire      # only segment padding


def test_auto_selection_thresholds(world):
    """The README table: rd below 32 KiB, tree in between, Rabenseifner
    at/above 64 KiB; bcast goes pipelined at/above 64 KiB."""
    comm = fresh(world, seed=19)
    picks = {}
    for nbytes in (1 << 10, 48 << 10, 128 << 10):
        vals = [np.ones(nbytes // 8, np.int64) for _ in range(N_RANKS)]
        h = mpi.iallreduce(comm, vals)
        comm.wait(h, max_ticks=900_000)
        picks[nbytes] = h.algorithm
    assert picks[1 << 10] == "allreduce_rd"
    assert picks[48 << 10] == "allreduce_tree"
    assert picks[128 << 10] == "allreduce_rab"
    bufs = [np.zeros(96 << 10, np.uint8) for _ in range(N_RANKS)]
    h = mpi.ibcast(comm, bufs)
    comm.wait(h, max_ticks=900_000)
    assert h.algorithm == "bcast_pipelined"


# --------------------------------------------------- checkpoint round-trips
def _ckpt_comm():
    return mpi.Communicator(
        N_RANKS, seed=17, cfg=SMALL_SEG_CFG,
        link_cfg=LinkConfig(loss=0.08, latency=2, jitter=2,
                            duplicate=0.03, reorder=0.1))


def _roundtrip_mid_collective(post, check):
    """Post a collective, advance mid-flight, snapshot; finish the
    original and a restored fresh communicator; both must agree
    bit-exactly and tick-exactly."""
    c1 = _ckpt_comm()
    h1 = post(c1)
    c1.progress(25)
    assert not h1.done, "snapshot must land mid-collective"
    snap = c1.checkpoint()
    c1.wait(h1, max_ticks=900_000)
    check(h1)
    end1, stats1 = c1.now, c1.link_stats()

    c2 = _ckpt_comm()
    handles = c2.restore(snap)
    (h2,) = handles.values()
    assert not h2.done
    c2.wait(h2, max_ticks=900_000)
    check(h2)
    assert c2.now == end1, "restored run must take the same ticks"
    assert stats1 == c2.link_stats()


def test_checkpoint_mid_rabenseifner_roundtrip():
    vals = [RNG.integers(0, 1 << 20, 4096).astype(np.int64)
            for _ in range(N_RANKS)]
    ref = np.sum(np.stack(vals), axis=0)

    def check(h):
        assert h.algorithm == "allreduce_rab"
        for o in h.result:
            np.testing.assert_array_equal(o, ref)

    _roundtrip_mid_collective(
        lambda c: mpi.iallreduce(c, [v.copy() for v in vals],
                                 algorithm="rab"), check)


def test_checkpoint_mid_pipelined_bcast_roundtrip():
    data = RNG.integers(0, 256, 16_000).astype(np.uint8)

    def check(h):
        assert h.algorithm == "bcast_pipelined"
        for b in h.result:
            np.testing.assert_array_equal(b, data)

    _roundtrip_mid_collective(
        lambda c: mpi.ibcast(
            c, [data.copy() if r == 2 else np.zeros_like(data)
                for r in range(N_RANKS)],
            root=2, algorithm="pipelined"), check)


# ------------------------------------------------ credit-managed rendezvous
def test_concurrent_segmented_collectives_share_credits(world):
    """K segmented collectives in flight at once must share the slot
    credits without deadlock; with only a few slots the receiver-side
    credit stalls become visible in the engine stats."""
    comm = fresh(world, seed=23)
    vals_a = [RNG.integers(0, 1 << 16, 4096).astype(np.int64)
              for _ in range(N_RANKS)]
    vals_b = [RNG.integers(0, 1 << 16, 3072).astype(np.int64)
              for _ in range(N_RANKS)]
    data = RNG.integers(0, 256, 12_000).astype(np.uint8)
    bufs = [data.copy() if r == 0 else np.zeros_like(data)
            for r in range(N_RANKS)]
    hs = [mpi.iallreduce(comm, vals_a, algorithm="rab"),
          mpi.iallreduce(comm, vals_b, algorithm="rab"),
          mpi.ibcast(comm, bufs, root=0, algorithm="pipelined")]
    comm.waitall(hs, max_ticks=2_000_000)
    for o in hs[0].result:
        np.testing.assert_array_equal(o, np.sum(np.stack(vals_a), axis=0))
    for o in hs[1].result:
        np.testing.assert_array_equal(o, np.sum(np.stack(vals_b), axis=0))
    for b in bufs:
        np.testing.assert_array_equal(b, data)
    stats = comm.stats()
    assert all("credit_stalls" in s and "window_stalls" in s
               for s in stats)
    # three concurrent segmented collectives over 4 slots per receiver
    # must have throttled somewhere
    assert sum(s["credit_stalls"] + s["window_stalls"]
               for s in stats) > 0


def test_cts_carries_credit_and_sender_window_follows(world):
    """The end-to-end protocol: a CTS advertises the receiver's remaining
    leases and the sender's per-destination window tracks it."""
    comm = fresh(world, seed=29, loss=0.0)
    vals = [RNG.integers(0, 1 << 16, 4096).astype(np.int64)
            for _ in range(N_RANKS)]
    h = mpi.iallreduce(comm, vals, algorithm="rab")
    comm.wait(h, max_ticks=600_000)
    windows = [w for e in comm.engines for w in e._rdv_window.values()]
    assert windows and all(1 <= w <= SMALL_SEG_CFG.n_rdv_slots
                           for w in windows)


# ------------------------------------------------------ persistent requests
def test_persistent_requests_reuse_caches(world):
    """send_init/recv_init handles must not touch the datatype commit
    cache or rebuild NIC contexts across start() calls — the whole point
    of persisting the plan."""
    comm = fresh(world, seed=31)
    seg = comm.cfg.coll_seg_bytes
    mem = RNG.integers(0, 256, seg).astype(np.uint8)
    buf = np.zeros(seg, np.uint8)
    ps = comm.send_init(0, 3, mem, tag=5, datatype=comm.seg_dtype)
    pr = comm.recv_init(3, buf, source=0, tag=5)
    commits0 = dict(mpi.COMMIT_COUNTERS)
    builds0 = dict(apps.MPI_CONTEXT_BUILDS)
    for it in range(3):
        mem[:] = RNG.integers(0, 256, seg)
        buf[:] = 0
        comm.waitall(comm.start_all([pr, ps]), max_ticks=300_000)
        np.testing.assert_array_equal(buf, mem)
    assert ps.starts == pr.starts == 3
    assert mpi.COMMIT_COUNTERS == commits0, \
        "persistent start() recommitted a datatype"
    assert apps.MPI_CONTEXT_BUILDS == builds0, \
        "persistent start() rebuilt a NIC context"
    # restart while in flight is a caller error
    req = ps.start()
    with pytest.raises(AssertionError):
        ps.start()
    comm.waitall([req, pr.start()], max_ticks=300_000)


# ------------------------------------------------------------ observability
def test_fabric_stats_surface_unroutable_and_deferred():
    """Frames to unknown MACs are counted (not silently dropped), and the
    per-link deferred counter reports batch-pressure stalls (more ready
    frames than the NIC ingress batch drains per tick)."""
    nodes = [Node(f"n{i}", pkt.node_mac(i), [apps.make_null_context()],
                  batch=4) for i in range(2)]
    fab = Fabric(nodes, link_cfg=LinkConfig(latency=1), seed=0)
    ghost = pkt.make_udp(np.zeros(8, np.uint8), src_mac=pkt.node_mac(0),
                         dst_mac=pkt.node_mac(77))
    real = [pkt.make_udp(np.full(8, i, np.uint8), src_mac=pkt.node_mac(0),
                         dst_mac=pkt.node_mac(1)) for i in range(8)]
    outbound = [[] for _ in nodes]
    fab._route([ghost] + real, outbound)
    assert fab.stats()["unroutable"] == 1
    assert len(outbound[1]) == 8 and not outbound[0]
    # deliver the 8 routed frames through the real push path: with an
    # ingress batch of 4 the first draining tick must defer the rest
    fab._flush_outbound(outbound)
    for _ in range(4):
        fab.tick()
    st = fab.stats()
    assert st["deferred_total"] > 0, st
    assert st["delivered_total"] == 8, st
    assert st["links"][1]["deferred"] == st["deferred_total"]


def test_collective_handles_report_bytes_wire(world):
    comm = fresh(world, seed=37, loss=0.0)
    vals = [np.ones(2048, np.int64) for _ in range(N_RANKS)]  # 16 KiB
    h = mpi.iallreduce(comm, vals, algorithm="rab")
    comm.wait(h, max_ticks=600_000)
    # every rank moves ~2·(n-1)/n vectors; padding rounds up per segment
    assert h.bytes_wire >= 2 * (N_RANKS - 1) * 2048 * 8 // N_RANKS


# -------------------------------------------------------- trainer grad sync
def test_fabric_grad_sync_mean_and_overlap():
    """FabricGradSync reduces a gradient pytree to the exact mean on every
    shard and reports overlap instrumentation."""
    from repro.train.manual_dp import FabricGradSync
    n = 3
    comm = mpi.Communicator(n, seed=5, cfg=SMALL_SEG_CFG,
                            link_cfg=LinkConfig(loss=0.02, latency=1))
    rng = np.random.default_rng(7)
    grads = [dict(w=rng.normal(size=(64, 32)).astype(np.float32),
                  b=rng.normal(size=(64,)).astype(np.float32))
             for _ in range(n)]
    sync = FabricGradSync(comm)
    sync.post([{k: g[k].copy() for k in g} for g in grads])
    while not sync.progress(8):       # the backprop hook
        pass
    means = sync.wait()
    for key in ("w", "b"):
        ref = np.mean(np.stack([g[key] for g in grads]), axis=0,
                      dtype=np.float64)
        for m in means:
            # f32 sums in schedule order: compare against the f64 mean
            # with an f32-epsilon budget, and require every shard to hold
            # the bit-identical result (one reduction, one broadcast)
            np.testing.assert_allclose(m[key], ref, rtol=1e-5, atol=1e-6)
            np.testing.assert_array_equal(m[key], means[0][key])
    st = sync.last_stats
    assert st["overlap_ratio"] > 0 and st["grad_bytes"] == 64 * 32 * 4 + 64 * 4
    assert st["compute_ticks"] > 0 and st["total_ticks"] > 0
