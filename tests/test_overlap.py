"""Overlap engine + serving engine tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import shapes as sh
from repro.core import overlap
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def test_overlap_report_accounting():
    """Overlapped loop must produce identical results to sequential and
    report a sane R."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64))
                    .astype(np.float32))
    ingest = jax.jit(lambda x: x * 2.0)
    compute = jax.jit(lambda s, b: s @ w * 1e-3 + b.sum() * 0)
    feeds = [jnp.full((64, 64), float(i)) for i in range(6)]
    s0 = jnp.eye(64)
    out_seq, rep_seq = overlap.sequential_loop(ingest, compute, feeds, s0)
    out_ovl, rep_ovl = overlap.overlapped_loop(ingest, compute, feeds, s0)
    np.testing.assert_allclose(np.asarray(out_seq), np.asarray(out_ovl),
                               rtol=1e-6)
    assert 0.0 <= rep_ovl.overlap_ratio <= 1.0
    assert rep_ovl.steps == rep_seq.steps == 6


def test_fused_ingest_step():
    ingest = lambda x: x + 1.0
    step = lambda s, b: (s + b.sum())
    fused = overlap.fuse_ingest_into_step(ingest, step)
    out = fused(jnp.zeros(()), jnp.ones((4,)))
    assert float(out) == 8.0                      # sum(1+1 four times)


def test_serve_engine_greedy_matches_decode_loop():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = sh.prefill_batch_specs(cfg, 16, 2, concrete=True, rng=rng)
    engine = ServeEngine(model, params, max_len=32)
    state = engine.prefill(batch)
    toks, _ = engine.generate(state, steps=5)
    assert toks.shape == (2, 5)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab
    # greedy decode is deterministic
    state2 = engine.prefill(batch)
    toks2, _ = engine.generate(state2, steps=5)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_serve_engine_whisper_encdec():
    cfg = configs.get_smoke_config("whisper-tiny")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = sh.prefill_batch_specs(cfg, 8, 2, concrete=True, rng=rng)
    engine = ServeEngine(model, params, max_len=24)
    state = engine.prefill(batch)
    toks, _ = engine.generate(state, steps=4)
    assert toks.shape == (2, 4)
