"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import packet as pkt
from repro.kernels.checksum import ops as cops
from repro.kernels.checksum.ref import checksum_ref
from repro.kernels.ddt import ops as dops
from repro.kernels.ddt.ref import ddt_gather_ref
from repro.kernels.matcher import ops as mops
from repro.kernels.matcher.ref import match_ref


# ------------------------------------------------------------- ddt gather
@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.uint8,
                                   "bfloat16"])
@pytest.mark.parametrize("s,i", [(16, 16), (100, 777), (1000, 333),
                                 (513, 1025), (2048, 64)])
def test_ddt_gather_matches_ref(dtype, s, i):
    rng = np.random.default_rng(hash((s, i)) % 2**31)
    if dtype == "bfloat16":
        src = jnp.asarray(rng.normal(size=s).astype(np.float32),
                          jnp.bfloat16)
    elif np.issubdtype(np.dtype(dtype), np.floating):
        src = jnp.asarray(rng.normal(size=s).astype(dtype))
    else:
        src = jnp.asarray(rng.integers(0, 200, size=s).astype(dtype))
    idx = jnp.asarray(rng.integers(-1, s, size=i).astype(np.int32))
    out_k = dops.gather(src, idx, use_kernel=True)
    out_r = ddt_gather_ref(src, idx)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_ddt_gather_fill_value():
    src = jnp.arange(8, dtype=jnp.float32)
    idx = jnp.asarray([-1, 3, -1, 7], jnp.int32)
    out = dops.gather(src, idx, fill=0, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(out), [0, 3, 0, 7])


def test_ddt_pack_unpack_roundtrip_kernel():
    from repro.core import ddt as ddtlib
    c = ddtlib.commit(ddtlib.simple_ddt(), count=3)
    pack_idx, unpack_idx = ddtlib.element_maps(c, 4)
    rng = np.random.default_rng(0)
    mem = jnp.asarray(rng.normal(size=c.mem_bytes // 4).astype(np.float32))
    msg = dops.pack(mem, jnp.asarray(pack_idx), use_kernel=True)
    dst = jnp.zeros_like(mem)
    out = dops.unpack(msg, jnp.asarray(unpack_idx), dst, use_kernel=True)
    # every mapped position must round-trip
    mask = unpack_idx >= 0
    np.testing.assert_allclose(np.asarray(out)[mask],
                               np.asarray(mem)[mask], rtol=0)


# -------------------------------------------------------------- checksum
@pytest.mark.parametrize("n_pkts", [1, 5, 130])
def test_checksum_kernel_vs_ref_and_numpy(n_pkts):
    rng = np.random.default_rng(n_pkts)
    frames = [pkt.make_icmp_echo(
        rng.integers(0, 256, size=int(rng.integers(0, 900))).astype(
            np.uint8))
        for _ in range(n_pkts)]
    b = pkt.stack_frames(frames)
    k = cops.internet_checksum(b.data, b.length, start=pkt.L4_BASE,
                               use_kernel=True)
    r = checksum_ref(b.data, b.length, pkt.L4_BASE)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    # frames carry a correct embedded checksum => total checksum == 0
    np.testing.assert_array_equal(np.asarray(k), np.zeros(n_pkts))


def test_checksum_against_numpy_oracle_random_payloads():
    rng = np.random.default_rng(7)
    frames = []
    expected = []
    for ln in (0, 1, 2, 63, 64, 500):
        payload = rng.integers(0, 256, size=ln).astype(np.uint8)
        f = pkt.make_udp(payload)
        frames.append(f)
        expected.append(pkt.internet_checksum_np(f[pkt.L4_BASE:]))
    b = pkt.stack_frames(frames)
    k = cops.internet_checksum(b.data, b.length, start=pkt.L4_BASE,
                               use_kernel=True)
    np.testing.assert_array_equal(np.asarray(k), expected)


# --------------------------------------------------------------- matcher
def _tables():
    from repro.core import matching as m
    return m.MatchTables.build([m.ruleset_icmp_echo(),
                                m.ruleset_udp_pingpong(9999),
                                m.ruleset_slmp(9330)])


@pytest.mark.parametrize("n", [1, 7, 128, 200])
def test_matcher_kernel_vs_ref(n):
    rng = np.random.default_rng(n)
    frames = []
    for i in range(n):
        kind = i % 4
        payload = rng.integers(0, 256, size=32).astype(np.uint8)
        if kind == 0:
            frames.append(pkt.make_icmp_echo(payload))
        elif kind == 1:
            frames.append(pkt.make_udp(payload, dport=9999))
        elif kind == 2:
            frames.append(pkt.make_slmp(i, 0, pkt.SLMP_FLAG_EOM, payload))
        else:
            frames.append(pkt.make_udp(payload, dport=1234))  # no match
    b = pkt.stack_frames(frames)
    t = _tables()
    words = b.words()
    mk, ek = mops.match(words, t.rules, t.modes, use_kernel=True)
    mr, er = match_ref(words, t.rules, t.modes)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(ek), np.asarray(er))


def test_matcher_or_mode():
    from repro.core import matching as m
    rs = m.Ruleset(mode=m.MODE_OR,
                   rules=[m.RULE_IP_PROTO(pkt.IPPROTO_ICMP),
                          m.RULE_IP_PROTO(pkt.IPPROTO_UDP),
                          m.RULE_FALSE()],
                   eom=m.RULE_FALSE())
    t = m.MatchTables.build([rs])
    frames = [pkt.make_icmp_echo(np.zeros(8, np.uint8)),
              pkt.make_udp(np.zeros(8, np.uint8))]
    b = pkt.stack_frames(frames)
    for uk in (False, True):
        mm, _ = mops.match(b.words(), t.rules, t.modes, use_kernel=uk)
        assert bool(mm[0, 0]) and bool(mm[1, 0])


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("shape", [
    (2, 64, 64, 4, 2, 32, True, 0),      # causal GQA
    (1, 96, 96, 2, 1, 16, True, 32),     # causal + sliding window (MQA)
    (2, 48, 96, 4, 4, 32, False, 0),     # bidirectional (encoder/cross)
    (1, 32, 32, 2, 2, 64, True, 0),      # head_dim 64
])
def test_flash_attention_kernel_vs_refs(shape):
    from repro.kernels.flash_attention import ops as fops
    from repro.models import attention as A
    b, sq, sk, h, kv, d, causal, window = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.bfloat16)
    out_k = fops.flash_attention(q, k, v, causal=causal, window=window,
                                 use_kernel=True, block_q=32, block_k=32)
    out_r = fops.flash_attention(q, k, v, causal=causal, window=window,
                                 use_kernel=False)
    out_b = A.blockwise_attention(q, k, v, causal=causal, window=window,
                                  block_q=32, block_k=32)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=0.06)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_b, np.float32),
        atol=0.06)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_dtype_sweep(dtype):
    from repro.kernels.flash_attention import ops as fops
    rng = np.random.default_rng(0)
    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dt)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dt)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dt)
    out_k = fops.flash_attention(q, k, v, use_kernel=True,
                                 block_q=32, block_k=32)
    out_r = fops.flash_attention(q, k, v, use_kernel=False)
    assert out_k.dtype == dt
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=0.05)
