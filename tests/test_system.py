"""End-to-end behaviour tests: the full NIC pipeline running the paper's
three demonstrations (ping-pong, SLMP reliable transfer, MPI DDT
offload)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import apps, ddt as ddtlib, packet as pkt, slmp, spin_nic


@pytest.fixture(scope="module")
def pingpong_nic():
    return spin_nic.SpinNIC([apps.make_icmp_context(),
                             apps.make_udp_pingpong_context()], batch=8)


def test_icmp_echo_end_to_end(pingpong_nic):
    nic = pingpong_nic
    st = nic.init_state()
    payload = np.arange(64, dtype=np.uint8)
    req = pkt.make_icmp_echo(payload, seq=1)
    st, egress, to_host = nic.step(st, pkt.stack_frames([req], n=8))
    ev = np.asarray(egress.valid)
    assert ev.sum() == 1
    f = np.asarray(egress.data)[np.argmax(ev)]
    ln = int(np.asarray(egress.length)[np.argmax(ev)])
    assert f[pkt.ICMP_TYPE] == pkt.ICMP_ECHO_REPLY
    # checksum over the ICMP segment must verify (sum == 0)
    assert pkt.internet_checksum_np(f[pkt.L4_BASE:ln]) == 0
    # src/dst swapped
    assert f[pkt.IP_SRC:pkt.IP_SRC + 4].tolist() == [10, 0, 0, 2]
    # payload intact
    np.testing.assert_array_equal(f[pkt.L4_BASE + 8:ln], payload)


def test_udp_pingpong_and_passthrough(pingpong_nic):
    nic = pingpong_nic
    st = nic.init_state()
    frames = [pkt.make_udp(np.arange(10, dtype=np.uint8), dport=9999),
              pkt.make_udp(np.arange(10, dtype=np.uint8), dport=53)]
    st, egress, to_host = nic.step(st, pkt.stack_frames(frames, n=8))
    assert int(np.asarray(egress.valid).sum()) == 1      # only port 9999
    # the DNS-ish packet is forwarded to the host datapath (ARP-style)
    th = np.asarray(to_host.valid)
    assert th.sum() == 1
    fwd = np.asarray(to_host.data)[np.argmax(th)]
    assert int(pkt.read_u16(jnp.asarray(fwd), pkt.UDP_DPORT)) == 53


def test_slmp_reliable_transfer_with_acks():
    nic = spin_nic.SpinNIC([slmp.make_slmp_context()], host_bytes=1 << 16,
                           batch=16)
    st = nic.init_state()
    rng = np.random.default_rng(3)
    msg = rng.integers(0, 256, 7321).astype(np.uint8)
    frames = slmp.segment_message(
        msg, 77, slmp.SlmpSenderConfig(window=4))
    acks = 0
    for i in range(0, len(frames), 16):
        st, egress, _ = nic.step(st, pkt.stack_frames(frames[i:i + 16],
                                                      n=16))
        acks += len(slmp.parse_acks(egress))
    got = nic.read_host(st, 0, len(msg))
    np.testing.assert_array_equal(got, msg)
    assert acks == len(frames)                  # SYN on every segment
    comp, st = nic.pop_counters(st, slmp.COMPLETION_QUEUE)
    assert comp.tolist() == [77]
    # a pop is a drain: a second pop returns nothing until handlers push
    comp2, st = nic.pop_counters(st, slmp.COMPLETION_QUEUE)
    assert comp2.tolist() == []


def test_slmp_out_of_order_delivery():
    """SLMP reassembly is offset-addressed: segment order must not matter
    (message-level reliability mode)."""
    nic = spin_nic.SpinNIC([slmp.make_slmp_context()], host_bytes=1 << 16,
                           batch=8)
    st = nic.init_state()
    msg = np.arange(4000, dtype=np.uint8).astype(np.uint8)
    frames = slmp.segment_message(
        msg, 9, slmp.SlmpSenderConfig(window=4, mtu_payload=512))
    order = [2, 0, 3, 1, 6, 5, 4, 7]
    frames = [frames[i] for i in order[:len(frames)]]
    for f in frames:
        st, _, _ = nic.step(st, pkt.stack_frames([f], n=8))
    got = nic.read_host(st, 0, len(msg))
    np.testing.assert_array_equal(got, msg)


@pytest.mark.parametrize("ddt_name,count", [("simple", 4), ("complex", 3)])
def test_mpi_ddt_offload_end_to_end(ddt_name, count):
    """Paper §V-C: DDT messages over SLMP, window=1 (in-order), scattered
    into host memory by the handlers; result must equal the MPI unpack
    oracle."""
    d = ddtlib.simple_ddt() if ddt_name == "simple" else \
        ddtlib.complex_ddt()
    c = ddtlib.commit(d, count=count)
    nic = spin_nic.SpinNIC([apps.make_ddt_context(c, msgs_in_flight=4)],
                           host_bytes=1 << 18, batch=4)
    st = nic.init_state()
    rng = np.random.default_rng(42)
    mem_src = rng.integers(0, 256, c.mem_bytes).astype(np.uint8)
    message = ddtlib.pack_np(c, mem_src)
    frames = slmp.segment_message(
        message, 1, slmp.SlmpSenderConfig(window=1, port=9331,
                                          mtu_payload=128))
    for f in frames:                   # window=1: in-order, one per step
        st, egress, _ = nic.step(st, pkt.stack_frames([f], n=4))
        assert len(slmp.parse_acks(egress)) == 1       # per-packet ACK
    region = (1 % 4) * c.mem_bytes
    got = nic.read_host(st, region, c.mem_bytes)
    oracle = ddtlib.unpack_np(c, message, np.zeros(c.mem_bytes, np.uint8))
    np.testing.assert_array_equal(got, oracle)


def test_ddt_parallel_messages():
    """Multiple messages in flight (paper's parallelism recovery) land in
    disjoint host regions."""
    c = ddtlib.commit(ddtlib.simple_ddt(), count=2)
    nmsg = 4
    nic = spin_nic.SpinNIC([apps.make_ddt_context(c, msgs_in_flight=nmsg)],
                           host_bytes=1 << 18, batch=nmsg,
                           mpq_entries=64)
    st = nic.init_state()
    rng = np.random.default_rng(5)
    mems = [rng.integers(0, 256, c.mem_bytes).astype(np.uint8)
            for _ in range(nmsg)]
    msgs = [ddtlib.pack_np(c, m) for m in mems]
    frame_lists = [slmp.segment_message(
        msgs[i], i, slmp.SlmpSenderConfig(window=1, port=9331,
                                          mtu_payload=64))
        for i in range(nmsg)]
    nseg = len(frame_lists[0])
    for s in range(nseg):              # interleave one segment per message
        batch = pkt.stack_frames([fl[s] for fl in frame_lists], n=nmsg)
        st, _, _ = nic.step(st, batch)
    for i in range(nmsg):
        got = nic.read_host(st, i * c.mem_bytes, c.mem_bytes)
        oracle = ddtlib.unpack_np(c, msgs[i],
                                  np.zeros(c.mem_bytes, np.uint8))
        np.testing.assert_array_equal(got, oracle)


def test_alloc_drop_counter_on_flood():
    nic = spin_nic.SpinNIC([apps.make_udp_pingpong_context()], batch=256)
    st = nic.init_state()
    # flood with large frames: only 170 large slots exist -> drops
    payload = np.zeros(1400, np.uint8)
    frames = [pkt.make_udp(payload, dport=9999) for _ in range(256)]
    st, egress, _ = nic.step(st, pkt.stack_frames(frames))
    assert int(st.dropped) == 256 - 170
    assert int(np.asarray(egress.valid).sum()) == 170


def test_alloc_recycling_and_drop_accounting_across_steps():
    """Completion frees packet-buffer slots: repeated floods must (a) keep
    accepting the full FIFO depth each step — slots are recycled — and
    (b) accumulate the drop counter monotonically."""
    nic = spin_nic.SpinNIC([apps.make_udp_pingpong_context()], batch=256)
    st = nic.init_state()
    payload = np.zeros(1400, np.uint8)
    frames = [pkt.make_udp(payload, dport=9999) for _ in range(256)]
    batch = pkt.stack_frames(frames)
    for step in range(1, 4):
        st, egress, _ = nic.step(st, batch)
        # every step serves exactly the large-FIFO depth again
        assert int(np.asarray(egress.valid).sum()) == 170
        assert int(st.dropped) == step * (256 - 170)
    # allocator conserved capacity: a small trickle still succeeds
    st, egress, _ = nic.step(st, pkt.stack_frames(frames[:4], n=256))
    assert int(np.asarray(egress.valid).sum()) == 4
    assert int(st.dropped) == 3 * (256 - 170)
