"""Sharding-rule validation: for every architecture, the parameter /
cache / batch shardings must be consistent (divisibility) with the
production mesh axis sizes.  Runs in a subprocess with 64 fake host
devices and an (4, 16) mesh — same model-axis width as production, so
every divisibility decision the rules make is exercised — and lowers an
identity function with the shardings attached (cheap: no model compile).
"""
import json
import os
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import shapes as shp
from repro.models.model import build_model
from repro.parallel import sharding as shlib

mesh = jax.make_mesh((4, 16), ("data", "model"),
                     devices=jax.devices()[:64])
out = {}
for arch in configs.ARCHS:
    cfg = configs.get_config(arch)
    model = build_model(cfg)
    params = model.init_eval()
    for fsdp in (False, True):
        sh = shlib.param_shardings(params, cfg, mesh, fsdp=fsdp)
        jax.jit(lambda p: p, in_shardings=(sh,),
                out_shardings=sh).lower(params)     # divisibility check
    # decode cache shardings for the 32k cell shape (batch 128)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024))
    csh = shlib.cache_shardings(cache, cfg, mesh)
    jax.jit(lambda c: c, in_shardings=(csh,),
            out_shardings=csh).lower(cache)
    # batch shardings
    _, batch = shp.input_specs(cfg, "train_4k")
    bsh = shlib.batch_shardings(batch, mesh)
    jax.jit(lambda b: b, in_shardings=(bsh,),
            out_shardings=bsh).lower(batch)
    out[arch] = "ok"
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharding_rules_all_archs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", CODE], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(v == "ok" for v in out.values()), out
    assert len(out) == 10
