"""Tests for repro.net: link model invariants, MAC routing, two-node
SLMP reliability under loss, ping-pong, and fabric checkpointing."""
import jax
import numpy as np
import pytest

from repro.core import apps, packet as pkt, slmp
from repro.net import (Fabric, Link, LinkConfig, Node, PingPongClient,
                       SlmpSenderEngine)


def _frames(n, nbytes=32):
    return [pkt.make_udp(np.arange(nbytes, dtype=np.uint8))
            for _ in range(n)]


# ----------------------------------------------------------------- link
def test_link_lossless_delivers_everything():
    lk = Link(LinkConfig(loss=0.0, latency=2, capacity=64))
    st = lk.push(lk.init_state(), jax.random.PRNGKey(0),
                 pkt.stack_frames(_frames(16)), now=0)
    st, out = lk.pop(st, now=1, n=16)
    assert int(np.asarray(out.valid).sum()) == 0      # latency not elapsed
    st, out = lk.pop(st, now=2, n=16)
    assert int(np.asarray(out.valid).sum()) == 16
    assert lk.stats(st)["lost"] == 0
    # delivered frames carry their original bytes
    i = int(np.argmax(np.asarray(out.valid)))
    ln = int(np.asarray(out.length)[i])
    np.testing.assert_array_equal(np.asarray(out.data)[i, :ln],
                                  _frames(1)[0])


def test_link_total_loss_delivers_nothing():
    lk = Link(LinkConfig(loss=1.0, latency=1, capacity=64))
    st = lk.push(lk.init_state(), jax.random.PRNGKey(0),
                 pkt.stack_frames(_frames(8)), now=0)
    assert lk.stats(st)["lost"] == 8
    st, out = lk.pop(st, now=10, n=8)
    assert int(np.asarray(out.valid).sum()) == 0


def test_link_loss_is_deterministic_in_key():
    lk = Link(LinkConfig(loss=0.5, latency=1, capacity=64))
    batch = pkt.stack_frames(_frames(32))
    s1 = lk.push(lk.init_state(), jax.random.PRNGKey(7), batch, 0)
    s2 = lk.push(lk.init_state(), jax.random.PRNGKey(7), batch, 0)
    s3 = lk.push(lk.init_state(), jax.random.PRNGKey(8), batch, 0)
    assert lk.stats(s1) == lk.stats(s2)
    np.testing.assert_array_equal(np.asarray(s1.occupied),
                                  np.asarray(s2.occupied))
    assert 0 < lk.stats(s1)["lost"] < 32               # p=.5, n=32
    assert lk.stats(s3) != lk.stats(s1) or not np.array_equal(
        np.asarray(s3.deliver_at), np.asarray(s1.deliver_at))


def test_link_duplication_and_capacity_overflow():
    lk = Link(LinkConfig(loss=0.0, duplicate=1.0, latency=1, capacity=12))
    st = lk.push(lk.init_state(), jax.random.PRNGKey(0),
                 pkt.stack_frames(_frames(8)), now=0)
    s = lk.stats(st)
    assert s["duplicated"] == 8
    assert s["overflowed"] == 4                        # 16 candidates, 12 slots
    st, out = lk.pop(st, now=5, n=16)
    assert int(np.asarray(out.valid).sum()) == 12


def test_link_jitter_reorders():
    lk = Link(LinkConfig(loss=0.0, latency=1, jitter=6, capacity=128))
    st = lk.init_state()
    key = jax.random.PRNGKey(1)
    # stamp each frame's payload with its send order
    frames = []
    for i in range(32):
        f = pkt.make_udp(np.full(16, i, np.uint8))
        frames.append(f)
    st = lk.push(st, key, pkt.stack_frames(frames), now=0)
    seen = []
    for t in range(1, 12):
        st, out = lk.pop(st, now=t, n=32)
        v = np.asarray(out.valid)
        for i in np.flatnonzero(v):
            seen.append(int(np.asarray(out.data)[i, pkt.SLMP_BASE]))
    assert sorted(seen) == list(range(32))             # all arrive
    assert seen != list(range(32))                     # ...but not in order


# --------------------------------------------------------------- fabric
def _slmp_pair(nbytes, loss, seed=7, window=8, timeout=10, jitter=2,
               duplicate=0.0):
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 256, nbytes).astype(np.uint8)
    cfg = slmp.SlmpSenderConfig(
        window=window, mtu_payload=1024, timeout=timeout,
        src_mac=pkt.node_mac(0), dst_mac=pkt.node_mac(1))
    sender = SlmpSenderEngine(msg, msg_id=42, cfg=cfg)
    a = Node("sender", pkt.node_mac(0), [apps.make_null_context()],
             engines=[sender], batch=16)
    b = Node("recv", pkt.node_mac(1), [slmp.make_slmp_context()],
             batch=16, host_bytes=1 << 17)
    fab = Fabric([a, b],
                 link_cfg=LinkConfig(loss=loss, latency=2, jitter=jitter,
                                     duplicate=duplicate),
                 seed=seed)
    return fab, sender, b, msg


def test_fabric_slmp_lossless():
    fab, sender, b, msg = _slmp_pair(20_000, loss=0.0)
    fab.run(max_ticks=500)
    assert sender.done and not sender.failed
    assert sender.sender.retransmits == 0
    np.testing.assert_array_equal(b.read_host(0, len(msg)), msg)
    assert b.completions == [42]


def test_fabric_slmp_survives_heavy_loss():
    """Acceptance criterion: a multi-segment message completes at >=10%
    simulated loss, and the retransmission path actually fires."""
    fab, sender, b, msg = _slmp_pair(40_000, loss=0.15)
    fab.run(max_ticks=5000)
    assert sender.done and not sender.failed
    assert sender.sender.nseg > 10                      # multi-segment
    assert sender.sender.retransmits > 0                # retransmit fired
    assert fab.link_stats()[1]["lost"] > 0              # loss really applied
    np.testing.assert_array_equal(b.read_host(0, len(msg)), msg)
    assert 42 in b.completions


def test_fabric_slmp_survives_duplication_and_reordering():
    fab, sender, b, msg = _slmp_pair(20_000, loss=0.1, jitter=5,
                                     duplicate=0.2)
    fab.run(max_ticks=5000)
    assert sender.done
    np.testing.assert_array_equal(b.read_host(0, len(msg)), msg)


def test_fabric_unroutable_frames_counted():
    cfg = slmp.SlmpSenderConfig(window=2, mtu_payload=512,
                                src_mac=pkt.node_mac(0),
                                dst_mac=b"\xff\xff\xff\xff\xff\xff")
    sender = SlmpSenderEngine(np.zeros(1024, np.uint8), 1, cfg)
    a = Node("a", pkt.node_mac(0), [apps.make_null_context()],
             engines=[sender], batch=8)
    fab = Fabric([a], seed=0)
    for _ in range(3):
        fab.tick()
    assert fab.unroutable > 0


def test_fabric_pingpong_rtt():
    client = PingPongClient(count=3, proto="udp", src_mac=pkt.node_mac(0),
                            dst_mac=pkt.node_mac(1))
    a = Node("client", pkt.node_mac(0), [apps.make_null_context()],
             engines=[client], batch=8)
    b = Node("server", pkt.node_mac(1),
             [apps.make_udp_pingpong_context()], batch=8)
    fab = Fabric([a, b], link_cfg=LinkConfig(loss=0.0, latency=1), seed=0)
    fab.run(max_ticks=100)
    assert client.done
    assert client.rtts == [2, 2, 2]        # 1 tick out + 1 tick back


def test_fabric_checkpoint_restore_is_deterministic():
    fab, sender, b, msg = _slmp_pair(20_000, loss=0.15, seed=5)
    for _ in range(10):
        fab.tick()
    snap = fab.checkpoint()
    fab.run(max_ticks=2000)
    end1 = (fab.now, sender.sender.retransmits,
            b.read_host(0, len(msg)).copy())
    fab.restore(snap)
    fab.run(max_ticks=2000)
    end2 = (fab.now, sender.sender.retransmits,
            b.read_host(0, len(msg)).copy())
    assert end1[0] == end2[0] and end1[1] == end2[1]
    np.testing.assert_array_equal(end1[2], end2[2])
    np.testing.assert_array_equal(end1[2], msg)


def test_node_drains_counters_from_packet_mode_contexts():
    """Contexts without message_mode can still push_counter (icmp-host
    mode): the node must drain their notifications too."""
    client = PingPongClient(count=2, proto="icmp", src_mac=pkt.node_mac(0),
                            dst_mac=pkt.node_mac(1), timeout=8)
    a = Node("client", pkt.node_mac(0), [apps.make_null_context()],
             engines=[client], batch=8)
    b = Node("hostmode", pkt.node_mac(1), [apps.make_icmp_host_context()],
             batch=8)
    fab = Fabric([a, b], link_cfg=LinkConfig(loss=0.0, latency=1), seed=0)
    for _ in range(6):
        fab.tick()
    # icmp-host handler pushes pkt_len per matched frame; no replies come
    # back, so the client refires after its timeout — at least one push
    assert len(b.completions) >= 1


def test_slmp_sender_gives_up_after_max_retries():
    cfg = slmp.SlmpSenderConfig(window=2, mtu_payload=512, timeout=2,
                                max_retries=3)
    sender = slmp.SlmpSender(np.zeros(2048, np.uint8), 9, cfg)
    now = 0
    while not (sender.done or sender.failed):
        sender.poll(now)                   # frames vanish: 100% loss
        now += 1
        assert now < 1000
    assert sender.failed and not sender.done
