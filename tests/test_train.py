"""Training substrate tests: optimizer, checkpoint/restart/elastic,
trainer loop (incl. microbatch accumulation), packetized data pipeline,
gradient compression, fault supervisor."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs import shapes as sh
from repro.launch import faults
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train import data as datalib
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig


# ------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    ost = opt.init(params)
    cfg = opt.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                        weight_decay=0.0, schedule="constant")
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, ost, _ = opt.apply_updates(params, ost, g, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(opt.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_lr_schedule_shapes():
    cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        schedule="cosine")
    lrs = [float(opt.schedule_lr(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": [{"b": jnp.ones((3, 4), jnp.bfloat16)},
                       jnp.asarray(7)]}
    d = str(tmp_path)
    ckpt.save(d, 5, tree)
    ckpt.save(d, 10, jax.tree.map(lambda x: x * 2, tree))
    assert ckpt.latest_step(d) == 10
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = ckpt.restore(d, template)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(10) * 2)
    # older checkpoint still restorable
    restored5, _ = ckpt.restore(d, template, step=5)
    np.testing.assert_array_equal(np.asarray(restored5["a"]),
                                  np.arange(10))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.zeros((4,))})
    bad = {"w": jax.ShapeDtypeStruct((5,), jnp.float32)}
    with pytest.raises(ValueError):
        ckpt.restore(d, bad)


# ------------------------------------------------------- trainer + data
def _small_trainer(tmp_path=None, steps=8, micro=1, ckpt_every=0):
    cfg = configs.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    tcfg = TrainerConfig(steps=steps, microbatches=micro, log_every=2,
                         ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path) if tmp_path else "/tmp/x",
                         donate=False)
    ocfg = opt.OptConfig(lr=5e-3, warmup_steps=2, total_steps=200)
    return model, Trainer(model, ocfg, tcfg)


def _batches(cfg, n, batch=4, seq=24):
    rng = np.random.default_rng(0)
    pipe = datalib.SyntheticCorpus(cfg.vocab, seed=1)
    for i in range(n):
        toks = pipe.batch(i, batch, seq)
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "targets": jnp.asarray(toks[:, 1:])}


def test_trainer_loss_decreases(tmp_path):
    model, tr = _small_trainer(tmp_path, steps=30)
    params = model.init(jax.random.key(0))
    ost = opt.init(params)
    params, ost, hist = tr.fit(params, ost,
                               _batches(model.cfg, 30), resume=False)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_trainer_microbatch_equivalence(tmp_path):
    """Grad accumulation over 2 microbatches ≈ full-batch step."""
    cfg = configs.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = next(_batches(cfg, 1, batch=4))
    outs = {}
    for micro in (1, 2):
        tcfg = TrainerConfig(steps=1, microbatches=micro, donate=False)
        tr = Trainer(model, opt.OptConfig(lr=1e-3, warmup_steps=0,
                                          total_steps=10), tcfg)
        fn = tr.build_step()
        p2, _, m = fn(params, opt.init(params), batch)
        outs[micro] = (p2, float(m["loss"]))
    # same loss batch, nearly identical updated params
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(outs[1][0]),
                            jax.tree.leaves(outs[2][0])))
    assert d < 0.05


def test_trainer_checkpoint_restart(tmp_path):
    model, tr = _small_trainer(tmp_path, steps=6, ckpt_every=3)
    params = model.init(jax.random.key(0))
    ost = opt.init(params)
    p1, o1, _ = tr.fit(params, ost, _batches(model.cfg, 6), resume=False)
    assert ckpt.latest_step(str(tmp_path)) == 6
    # restart resumes from step 6 and runs 6 more
    model2, tr2 = _small_trainer(tmp_path, steps=6, ckpt_every=3)
    params2 = model2.init(jax.random.key(9))       # fresh (wrong) params
    p2, o2, _ = tr2.fit(params2, opt.init(params2),
                        _batches(model2.cfg, 12), resume=True)
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_packetized_pipeline_roundtrip():
    """Packets -> SpinIngest -> identical tokens to the raw corpus."""
    cfg = configs.get_smoke_config("qwen3-1.7b")
    pipe = datalib.PacketizedPipeline(vocab=cfg.vocab, batch=4, seq=16)
    ingest = datalib.SpinIngest(pipe)
    raw = pipe.packets_for_step(3)
    out = ingest(raw)
    expect = pipe.corpus.batch(3, 4, 16)
    np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                  expect[:, :-1])
    np.testing.assert_array_equal(np.asarray(out["targets"]),
                                  expect[:, 1:])


def test_prefetch_iterator_order():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    pipe = datalib.PacketizedPipeline(vocab=cfg.vocab, batch=2, seq=8)
    feeds = list(datalib.prefetch_iterator(pipe, steps=5))
    assert len(feeds) == 5
    ingest = datalib.SpinIngest(pipe)
    for i, f in enumerate(feeds):
        out = ingest(f)
        expect = pipe.corpus.batch(i, 2, 8)
        np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                      expect[:, :-1])


# ------------------------------------------------------------ compression
def test_compressed_allreduce_close_to_exact():
    from repro.parallel import compression as comp
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",),
                         devices=jax.devices()[:1])
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    specs = {"w": P()}
    fn = comp.make_compressed_allreduce(mesh, specs)
    err0 = jax.tree.map(lambda g: jnp.zeros_like(g), grads)
    out, new_err = fn(grads, err0)
    # single device: mean == value up to int8 quantization error
    scale = float(jnp.max(jnp.abs(grads["w"]))) / 127
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"]), atol=scale)
    # error feedback holds the residual
    resid = np.asarray(grads["w"]) - np.asarray(out["w"])
    np.testing.assert_allclose(np.asarray(new_err["w"]), resid, atol=1e-6)


def test_compression_error_feedback_unbiased_over_time():
    from repro.parallel import compression as comp
    g = jnp.asarray([1e-4, -3e-5, 2e-4, 0.5])   # tiny grads vs big scale
    err = jnp.zeros_like(g)
    total = np.zeros(4)
    for _ in range(200):
        out, err = comp.compress_psum_leaf(g, err, ())
        total += np.asarray(out)
    # quantum is max|g|/127 ≈ 3.9e-3; EF bounds the avg error by q/2/N
    np.testing.assert_allclose(total / 200, np.asarray(g), rtol=0.05,
                               atol=2.5e-5)


# ---------------------------------------------------------------- faults
def test_run_with_restarts_recovers(tmp_path):
    calls = {"n": 0}

    def make_state():
        return {"value": calls["n"]}

    def run(state, attempt):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"simulated node failure #{calls['n']}")
        return "done"

    result, report = faults.run_with_restarts(make_state, run,
                                              max_restarts=5)
    assert result == "done"
    assert report.restarts == 2
    assert len(report.errors) == 2


def test_nan_guard():
    g = faults.NaNGuard()
    g.check(1.0)
    with pytest.raises(FloatingPointError):
        g.check(float("nan"))


def test_fault_tolerant_training_resumes_from_checkpoint(tmp_path):
    """Full story: crash mid-training, supervisor restarts, training
    resumes from the atomic checkpoint and completes."""
    cfg = configs.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    crash_at = {"armed": True}

    def make_state():
        params = model.init(jax.random.key(0))
        return params, opt.init(params)

    def run(state, attempt):
        params, ost = state
        tcfg = TrainerConfig(steps=10, ckpt_every=2, log_every=1,
                             ckpt_dir=str(tmp_path), donate=False)
        tr = Trainer(model, opt.OptConfig(lr=1e-3, warmup_steps=0,
                                          total_steps=100), tcfg)

        def batches():
            for i, b in enumerate(_batches(cfg, 10)):
                if crash_at["armed"] and i == 5:
                    crash_at["armed"] = False
                    raise RuntimeError("preemption")
                yield b

        return tr.fit(params, ost, batches(), resume=True)

    result, report = faults.run_with_restarts(make_state, run,
                                              max_restarts=2)
    assert result is not None and report.succeeded
    assert report.restarts == 1
    assert ckpt.latest_step(str(tmp_path)) >= 10
