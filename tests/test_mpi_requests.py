"""Conformance suite for the MPI request layer: nonblocking handles
(``test`` never blocks, ``waitall`` mixes p2p and collective handles,
out-of-order waits), every nonblocking collective bit-exact against its
blocking counterpart on a 5-rank fabric at loss=0.05, checkpoint/restore
of a fabric mid-``iallreduce`` (seeded determinism against the
uncheckpointed continuation), and the job-wide datatype-commit / NIC
context caches staying flat across communicators.
"""
import numpy as np
import pytest

from repro import mpi
from repro.core import apps
from repro.core import ddt as ddtlib
from repro.net import LinkConfig

N_RANKS = 5
RNG = np.random.default_rng(777)
LOSSY = dict(loss=0.05, latency=2, jitter=2)


@pytest.fixture(scope="module")
def world():
    reg = mpi.DatatypeRegistry()
    ids = dict(
        simple=reg.register(ddtlib.simple_ddt(), count=64, name="simple"),
        # big enough that a rendezvous transfer spans many ticks — the
        # checkpoint test snapshots mid-flight
        big=reg.register(ddtlib.simple_ddt(), count=1024, name="big"),
    )
    comm = mpi.Communicator(N_RANKS, registry=reg, seed=0,
                            link_cfg=LinkConfig(**LOSSY))
    return comm, ids


def fresh(world, seed=0, **link_kw):
    comm, ids = world
    cfg = dict(LOSSY, **link_kw)
    comm.rewire(link_cfg=LinkConfig(**cfg), seed=seed)
    return comm, ids


# ----------------------------------------------------------- test() / wait
def test_test_before_completion_returns_false_without_blocking(world):
    comm, _ = fresh(world, seed=1)
    buf = np.zeros(256, np.uint8)
    req = comm.irecv(1, buf, source=0, tag=9)
    t0 = comm.now
    for _ in range(5):
        assert req.test() is False
    assert comm.now == t0, "test() must not tick the fabric"
    assert comm.test(req) is False
    msg = RNG.integers(0, 256, 200).astype(np.uint8)
    s = comm.isend(0, 1, msg, tag=9)
    assert s.test() is False            # still queued, no ticks yet
    comm.waitall([req, s])
    assert req.test() is True and s.test() is True
    assert comm.test(req, s) is True
    np.testing.assert_array_equal(buf[:200], msg)


def test_request_wait_method(world):
    comm, _ = fresh(world, seed=2)
    msg = RNG.integers(0, 256, 300).astype(np.uint8)
    buf = np.zeros(300, np.uint8)
    r = comm.irecv(2, buf, source=4, tag=1)
    comm.isend(4, 2, msg, tag=1)
    r.wait()                            # handle-level MPI_Wait
    np.testing.assert_array_equal(buf, msg)


def test_waitall_mixed_p2p_and_collective_handles(world):
    comm, _ = fresh(world, seed=3)
    n = comm.n_ranks
    msg = RNG.integers(0, 256, 400).astype(np.uint8)
    buf = np.zeros(400, np.uint8)
    vals = [RNG.integers(0, 1 << 20, 96).astype(np.int64) for _ in range(n)]
    bdat = RNG.normal(size=128).astype(np.float32)
    bbufs = [bdat.copy() if r == 0 else np.zeros(128, np.float32)
             for r in range(n)]
    reqs = [comm.irecv(3, buf, source=0, tag=7),
            comm.isend(0, 3, msg, tag=7),
            mpi.ibcast(comm, bbufs, root=0),
            mpi.iallreduce(comm, vals),
            mpi.ibarrier(comm)]
    assert not any(r.done for r in reqs)
    comm.waitall(reqs, max_ticks=300_000)
    np.testing.assert_array_equal(buf, msg)
    for b in bbufs:
        np.testing.assert_array_equal(b, bdat)
    ref = np.sum(vals, axis=0)
    for o in reqs[3].result:
        np.testing.assert_array_equal(o, ref)


def test_out_of_order_waits(world):
    """Waiting on a later handle first must complete the earlier ones it
    overtakes; waiting on them afterwards is a no-op."""
    comm, _ = fresh(world, seed=4)
    msgs = [RNG.integers(0, 256, 300 + i).astype(np.uint8)
            for i in range(3)]
    bufs = [np.zeros(512, np.uint8) for _ in range(3)]
    recvs = [comm.irecv(1, bufs[i], source=0, tag=i) for i in range(3)]
    sends = [comm.isend(0, 1, msgs[i], tag=i) for i in range(3)]
    comm.wait(recvs[2], max_ticks=100_000)    # newest first
    # non-overtaking: everything the sender emitted before tag 2 matched
    assert recvs[0].done and recvs[1].done
    ticks = comm.wait(recvs[0], recvs[1], *sends)
    for i in range(3):
        np.testing.assert_array_equal(bufs[i][:300 + i], msgs[i])


def test_collective_handle_completion_is_plan_wide(world):
    comm, _ = fresh(world, seed=5)
    vals = [RNG.normal(size=64) for _ in range(comm.n_ranks)]
    h = mpi.iallreduce(comm, vals, algorithm="rd")
    assert isinstance(h, mpi.CollRequest)
    assert h.algorithm == "allreduce_rd"
    comm.wait(h, max_ticks=300_000)
    # every rank's output present and identical — one handle, whole plan
    assert len(h.result) == comm.n_ranks
    for o in h.result[1:]:
        np.testing.assert_array_equal(o, h.result[0])


# -------------------------------------- nonblocking ≡ blocking, bit-exact
def _payloads(n, seed):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=100).astype(np.float64) for _ in range(n)]


@pytest.mark.parametrize("which", ["bcast", "reduce", "allreduce",
                                   "alltoall", "alltoallv", "barrier"])
def test_nonblocking_bit_exact_vs_blocking(world, which):
    """Each nonblocking collective, driven with interleaved manual
    progress, produces bit-identical results to its blocking counterpart
    on an identically-seeded lossy fabric."""
    comm, _ = fresh(world, seed=11)
    n = comm.n_ranks

    def build_inputs():
        rng = np.random.default_rng(42)
        if which == "bcast":
            d = rng.normal(size=200).astype(np.float32)
            return [d.copy() if r == 1 else np.zeros(200, np.float32)
                    for r in range(n)]
        if which in ("reduce", "allreduce"):
            return [rng.normal(size=128) for _ in range(n)]
        if which == "alltoall":
            return [rng.integers(0, 1 << 30, (n, 40)).astype(np.int64)
                    for _ in range(n)]
        if which == "alltoallv":
            return [[rng.integers(0, 256, ((r + 2 * j) % 5) * 32)
                     .astype(np.uint8) for j in range(n)]
                    for r in range(n)]
        return None

    def run(nonblocking):
        comm.rewire(link_cfg=LinkConfig(**LOSSY), seed=11)
        inp = build_inputs()
        if nonblocking:
            h = dict(bcast=lambda: mpi.ibcast(comm, inp, root=1),
                     reduce=lambda: mpi.ireduce(comm, inp, root=2),
                     allreduce=lambda: mpi.iallreduce(comm, inp),
                     alltoall=lambda: mpi.ialltoall(comm, inp),
                     alltoallv=lambda: mpi.ialltoallv(comm, inp),
                     barrier=lambda: mpi.ibarrier(comm))[which]()
            while not h.test():             # overlap-style driving
                comm.progress(3)
            out = h.result
        else:
            out = dict(bcast=lambda: mpi.bcast(comm, inp, root=1),
                       reduce=lambda: mpi.reduce(comm, inp, root=2),
                       allreduce=lambda: mpi.allreduce(comm, inp),
                       alltoall=lambda: mpi.alltoall(comm, inp),
                       alltoallv=lambda: mpi.alltoallv(comm, inp),
                       barrier=lambda: mpi.barrier(comm))[which]()
        if which == "bcast":
            out = inp                       # in-place semantics
        if which == "barrier":
            out = None                      # completion is the contract
        return out, comm.now

    out_nb, _ = run(nonblocking=True)
    out_bl, _ = run(nonblocking=False)

    def flatten(x):
        if x is None:
            return []
        if isinstance(x, np.ndarray):
            return [x]
        return [a for sub in x for a in flatten(sub)]

    nb, bl = flatten(out_nb), flatten(out_bl)
    assert len(nb) == len(bl)
    for a, b in zip(nb, bl):
        np.testing.assert_array_equal(a, b)   # bit-exact


# --------------------------------------------------- checkpoint round-trip
def _ckpt_world(registry):
    return mpi.Communicator(
        N_RANKS, registry=registry, seed=17,
        link_cfg=LinkConfig(loss=0.08, latency=2, jitter=2,
                            duplicate=0.03, reorder=0.1))


def test_checkpoint_mid_iallreduce_roundtrip(world):
    """Snapshot a lossy fabric mid-``iallreduce`` (plus a typed rendezvous
    p2p in flight), restore into a fresh object graph, finish both, and
    get bit-identical results and identical per-link loss/dup/reorder
    counters to the uncheckpointed continuation."""
    comm, ids = world
    reg = comm.registry
    c = reg.committed(ids["big"])
    rng = np.random.default_rng(5)
    vals = [rng.integers(0, 1 << 20, 512).astype(np.int64)
            for _ in range(N_RANKS)]
    ref = np.sum(vals, axis=0)
    mem = rng.integers(0, 256, c.mem_bytes).astype(np.uint8)
    oracle = ddtlib.unpack_np(c, ddtlib.pack_np(c, mem),
                              np.zeros(c.mem_bytes, np.uint8))

    # ---- original run: post, advance mid-flight, snapshot, continue
    c1 = _ckpt_world(reg)
    buf1 = np.zeros(c.mem_bytes, np.uint8)
    p2p_r = c1.irecv(3, buf1, source=1, tag=2)
    p2p_s = c1.isend(1, 3, mem, tag=2, datatype=ids["big"])
    h1 = mpi.iallreduce(c1, [v.copy() for v in vals], algorithm="rd")
    c1.progress(20)
    assert not h1.done, "checkpoint must land mid-collective"
    assert not p2p_r.done, "checkpoint must land mid-rendezvous"
    snap = c1.checkpoint()
    rid_recv, rid_send = p2p_r.rid, p2p_s.rid
    c1.waitall([h1, p2p_r, p2p_s], max_ticks=300_000)
    for o in h1.result:
        np.testing.assert_array_equal(o, ref)
    np.testing.assert_array_equal(buf1, oracle)
    end1, stats1 = c1.now, c1.link_stats()

    # ---- fresh object graph, revived from the snapshot
    c2 = _ckpt_world(reg)
    handles = c2.restore(snap)
    assert list(handles) and not any(h.done for h in handles.values())
    h2 = next(iter(handles.values()))
    # the p2p requests were revived inside the engine snapshots
    r2 = c2.engines[3]._reqs[rid_recv]
    s2 = c2.engines[1]._reqs[rid_send]
    c2.run_until(lambda: h2.done and r2.done and s2.done,
                 max_ticks=300_000)
    for o in h2.result:
        np.testing.assert_array_equal(o, ref)
    np.testing.assert_array_equal(r2.buf, oracle)
    assert c2.now == end1, "restored run must take the same ticks"
    for s1, s2 in zip(stats1, c2.link_stats()):
        assert s1 == s2, "per-link drop/dup/reorder counters must match"


def test_checkpoint_is_nonperturbing(world):
    """Taking a snapshot must not change the run that continues."""
    comm, _ = fresh(world, seed=23)
    vals = [RNG.integers(0, 1 << 16, 64).astype(np.int64)
            for _ in range(comm.n_ranks)]
    h = mpi.iallreduce(comm, vals, algorithm="tree")
    comm.progress(15)
    comm.checkpoint()                       # discarded
    comm.wait(h, max_ticks=300_000)
    end_with = comm.now

    comm.rewire(link_cfg=LinkConfig(**LOSSY), seed=23)
    h = mpi.iallreduce(comm, vals, algorithm="tree")
    comm.progress(15)
    comm.wait(h, max_ticks=300_000)
    assert comm.now == end_with


# ------------------------------------------- datatype commit / NIC caches
def test_datatype_recommit_stays_flat_across_communicators(world):
    """Two communicators reusing the same (ddt, count) must not recommit
    the datatype nor rebuild/re-upload the NIC DDT context — guards the
    job-wide commit cache and the NIC cache."""
    comm, _ = world                         # module NIC already built
    vec = ddtlib.Vector(count=16, blocklen=2, stride=4,
                        base=ddtlib.MPI_FLOAT)

    reg1 = mpi.DatatypeRegistry()
    reg1.register(vec, count=8, name="v")
    commits_after_first = mpi.COMMIT_COUNTERS["commits"]

    reg2 = mpi.DatatypeRegistry()
    reg2.register(vec, count=8, name="v")   # same (ddt, count)
    assert mpi.COMMIT_COUNTERS["commits"] == commits_after_first, \
        "second registry recommitted a cached (ddt, count)"
    assert mpi.COMMIT_COUNTERS["hits"] >= 1

    comm_a = mpi.Communicator(2, registry=reg1, seed=0)
    builds = dict(apps.MPI_CONTEXT_BUILDS)
    comm_b = mpi.Communicator(2, registry=reg2, seed=1)
    assert apps.MPI_CONTEXT_BUILDS == builds, \
        "NIC context rebuilt although tables and geometry are identical"
    assert comm_b.nic is comm_a.nic         # shared compiled datapath

    # the cached NIC still moves typed data correctly on both comms
    cid = reg2.resolve((vec, 8))
    c = reg2.committed(cid)
    mem = RNG.integers(0, 256, c.mem_bytes).astype(np.uint8)
    buf = np.zeros(c.mem_bytes, np.uint8)
    r = comm_b.irecv(1, buf, source=0, tag=1)
    s = comm_b.isend(0, 1, mem, tag=1, datatype=cid)
    comm_b.waitall([r, s])
    oracle = ddtlib.unpack_np(c, ddtlib.pack_np(c, mem),
                              np.zeros(c.mem_bytes, np.uint8))
    np.testing.assert_array_equal(buf, oracle)


def test_log_step_algorithms_beat_linear_round_count(world):
    """The schedule metadata the bench records: recursive doubling takes
    ⌈log₂ n⌉ rounds (+2 fold rounds off powers of two) where the linear
    baseline takes n−1 — a strict win for every power-of-two rank count
    (the 8-rank case is asserted end-to-end by bench_mpi)."""
    comm, _ = fresh(world, seed=31)
    n = comm.n_ranks                         # 5: pof2=4, rem=1
    vals = [np.ones(8, np.int64) for _ in range(n)]
    h_rd = mpi.iallreduce(comm, vals, algorithm="rd")
    h_lin = mpi.iallreduce(comm, vals, algorithm="linear")
    comm.waitall([h_rd, h_lin], max_ticks=300_000)
    pof2 = 1 << (n.bit_length() - 1)
    want_rd = pof2.bit_length() - 1 + (2 if n != pof2 else 0)
    assert h_rd.rounds == want_rd <= h_lin.rounds
    assert h_lin.rounds == n - 1
    # at any power of two the log-step schedule is strictly shorter
    for m in (4, 8, 16, 64, 256):
        assert m.bit_length() - 1 < m - 1
    mats = [np.ones((comm.n_ranks, 4), np.int64)
            for _ in range(comm.n_ranks)]
    h_br = mpi.ialltoall(comm, mats, algorithm="bruck")
    h_pw = mpi.ialltoall(comm, mats, algorithm="pairwise")
    comm.waitall([h_br, h_pw], max_ticks=300_000)
    assert h_br.rounds < h_pw.rounds
    assert h_br.msgs_total < h_pw.msgs_total
