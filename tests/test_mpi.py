"""repro.mpi: point-to-point semantics (wildcards, unexpected queue,
out-of-order arrival over lossy links), NIC-offloaded datatype receives
against the numpy dataloop oracle, and every collective against numpy
references — all on a 5-rank fabric with loss/jitter enabled.

One module-scoped Communicator is shared (its jitted NIC datapath compiles
once); each test rewires fresh engines/links onto the same nodes.
"""
import numpy as np
import pytest

from repro import mpi
from repro.core import ddt as ddtlib
from repro.net import LinkConfig

N_RANKS = 5
RNG = np.random.default_rng(1234)


@pytest.fixture(scope="module")
def world():
    reg = mpi.DatatypeRegistry()
    ids = dict(
        simple=reg.register(ddtlib.simple_ddt(), count=64, name="simple"),
        complex=reg.register(ddtlib.complex_ddt(), count=256,
                             name="complex"),
        small=reg.register(ddtlib.Vector(8, 2, 4, ddtlib.MPI_FLOAT),
                           count=4, name="small"),
    )
    comm = mpi.Communicator(N_RANKS, registry=reg, seed=0)
    return comm, ids


def fresh(world, loss=0.05, seed=0, jitter=2, duplicate=0.0, reorder=0.0):
    comm, ids = world
    comm.rewire(link_cfg=LinkConfig(loss=loss, latency=2, jitter=jitter,
                                    duplicate=duplicate, reorder=reorder),
                seed=seed)
    return comm, ids


# ------------------------------------------------------------------- p2p
def test_p2p_eager_roundtrip(world):
    comm, _ = fresh(world, loss=0.0, jitter=0)
    a = RNG.integers(0, 256, 2000).astype(np.uint8)
    b = RNG.integers(0, 256, 999).astype(np.uint8)
    buf_a = np.zeros(4096, np.uint8)
    buf_b = np.zeros(4096, np.uint8)
    reqs = [comm.irecv(1, buf_a, source=0, tag=5),
            comm.irecv(0, buf_b, source=1, tag=6),
            comm.isend(0, 1, a, tag=5),
            comm.isend(1, 0, b, tag=6)]
    comm.wait(*reqs)
    np.testing.assert_array_equal(buf_a[:2000], a)
    np.testing.assert_array_equal(buf_b[:999], b)
    assert reqs[0].source == 0 and reqs[0].tag == 5 and reqs[0].nbytes == 2000
    assert reqs[1].source == 1 and reqs[1].tag == 6 and reqs[1].nbytes == 999


def test_p2p_wildcard_source_and_tag(world):
    comm, _ = fresh(world, loss=0.08, seed=3)
    msgs = {s: RNG.integers(0, 256, 100 + s).astype(np.uint8)
            for s in (1, 2, 3, 4)}
    bufs = [np.zeros(256, np.uint8) for _ in range(4)]
    recvs = [comm.irecv(0, bufs[i], source=mpi.ANY_SOURCE, tag=mpi.ANY_TAG)
             for i in range(4)]
    sends = [comm.isend(s, 0, msgs[s], tag=10 + s) for s in msgs]
    comm.wait(*recvs, *sends)
    # every sender matched exactly once; payload identified by the
    # status fields the wildcard receive reported
    seen = sorted(r.source for r in recvs)
    assert seen == [1, 2, 3, 4]
    for r, buf in zip(recvs, bufs):
        assert r.tag == 10 + r.source and r.nbytes == 100 + r.source
        np.testing.assert_array_equal(buf[:r.nbytes], msgs[r.source])


def test_p2p_out_of_order_posting_under_loss(world):
    """Receives posted in reverse tag order still match their tags even
    though segments arrive scrambled by the lossy, jittery wire."""
    comm, _ = fresh(world, loss=0.1, jitter=4, reorder=0.2, seed=9)
    msgs = [RNG.integers(0, 256, 1500).astype(np.uint8) for _ in range(3)]
    bufs = [np.zeros(1500, np.uint8) for _ in range(3)]
    # post tag 2, then 1, then 0 — sender emits 0, 1, 2
    recvs = {t: comm.irecv(1, bufs[t], source=0, tag=t)
             for t in (2, 1, 0)}
    sends = [comm.isend(0, 1, msgs[t], tag=t) for t in (0, 1, 2)]
    comm.wait(*recvs.values(), *sends, max_ticks=50_000)
    for t in range(3):
        assert recvs[t].tag == t
        np.testing.assert_array_equal(bufs[t], msgs[t])


def test_p2p_unexpected_message_queue(world):
    comm, _ = fresh(world, loss=0.0, jitter=0)
    msg = RNG.integers(0, 256, 800).astype(np.uint8)
    send = comm.isend(2, 3, msg, tag=77)
    comm.progress(60)                      # message arrives, no recv posted
    assert comm.engines[3].stats["unexpected"] == 1
    buf = np.zeros(800, np.uint8)
    recv = comm.irecv(3, buf, source=mpi.ANY_SOURCE, tag=77)
    assert recv.done                       # matched straight from the queue
    comm.wait(send)
    np.testing.assert_array_equal(buf, msg)
    assert recv.source == 2


def test_p2p_self_send(world):
    comm, _ = fresh(world, loss=0.0)
    msg = RNG.integers(0, 256, 64).astype(np.uint8)
    buf = np.zeros(64, np.uint8)
    s = comm.isend(2, 2, msg, tag=1)
    r = comm.irecv(2, buf, source=2, tag=1)
    assert s.done and r.done
    np.testing.assert_array_equal(buf, msg)


def test_p2p_many_messages_reuse_staging_slots(world):
    """More in-flight messages than staging slots per sender: the eager
    flow-control gate serializes slot reuse without losing a message."""
    comm, _ = fresh(world, loss=0.05, seed=4)
    n_msgs = 3 * comm.cfg.eager_slots_per_src
    msgs = [RNG.integers(0, 256, 600 + i).astype(np.uint8)
            for i in range(n_msgs)]
    bufs = [np.zeros(1024, np.uint8) for _ in range(n_msgs)]
    recvs = [comm.irecv(4, bufs[i], source=0, tag=i)
             for i in range(n_msgs)]
    sends = [comm.isend(0, 4, msgs[i], tag=i) for i in range(n_msgs)]
    comm.wait(*recvs, *sends, max_ticks=100_000)
    for i in range(n_msgs):
        np.testing.assert_array_equal(bufs[i][:600 + i], msgs[i])


def test_p2p_non_overtaking_same_source_and_tag(world):
    """MPI non-overtaking: two messages with the same (source, tag) must
    match posted receives in *send* order.  An eager message's envelope
    (FIN, sent only after all segments are ACKed) races the very next
    rendezvous message's RTS, which leaves the sender immediately — the
    matching layer must reorder them by send sequence."""
    comm, ids = fresh(world, loss=0.0, jitter=0)
    c = comm.registry.committed(ids["simple"])
    small = RNG.integers(0, 256, 512).astype(np.uint8)          # eager
    mem = RNG.integers(0, 256, c.mem_bytes).astype(np.uint8)    # rendezvous
    buf1 = np.zeros(512, np.uint8)
    buf2 = np.zeros(c.mem_bytes, np.uint8)
    r1 = comm.irecv(1, buf1, source=0, tag=5)    # must get the eager msg
    r2 = comm.irecv(1, buf2, source=0, tag=5)    # must get the rdv msg
    s1 = comm.isend(0, 1, small, tag=5)
    s2 = comm.isend(0, 1, mem, tag=5, datatype=ids["simple"])
    comm.wait(r1, r2, s1, s2, max_ticks=100_000)
    np.testing.assert_array_equal(buf1, small)
    ref = ddtlib.unpack_np(c, ddtlib.pack_np(c, mem),
                           np.zeros(c.mem_bytes, np.uint8))
    np.testing.assert_array_equal(buf2, ref)
    assert r1.nbytes == 512 and r2.nbytes == c.msg_bytes


# ------------------------------------------------- offloaded datatype recv
@pytest.mark.parametrize("name", ["simple", "complex"])
def test_rendezvous_nic_unpack_matches_oracle(world, name):
    """Large typed messages go rendezvous: the NIC scatters payload bytes
    through the committed index map into the posted region.  Must equal
    the numpy dataloop oracle — including holes (buffer bytes the datatype
    does not touch keep their prior contents) and last-occurrence-wins on
    the overlapping 'complex' layout — under loss + duplication."""
    comm, ids = fresh(world, loss=0.12, jitter=3, duplicate=0.05, seed=21)
    c = comm.registry.committed(ids[name])
    assert c.msg_bytes >= comm.cfg.eager_threshold   # really rendezvous
    mem = RNG.integers(0, 256, c.mem_bytes).astype(np.uint8)
    buf = np.full(c.mem_bytes, 0xAA, np.uint8)
    r = comm.irecv(3, buf, source=1, tag=2)
    s = comm.isend(1, 3, mem, tag=2, datatype=ids[name])
    comm.wait(r, s, max_ticks=100_000)
    ref = ddtlib.unpack_np(c, ddtlib.pack_np(c, mem),
                           np.full(c.mem_bytes, 0xAA, np.uint8))
    np.testing.assert_array_equal(buf, ref)
    assert comm.engines[1].stats["rdv_sent"] == 1
    assert sum(l["lost"] for l in comm.link_stats()) > 0   # loss applied


def test_eager_typed_message_host_unpack(world):
    """Typed messages below the threshold take the eager path and unpack
    on the host — same result, no NIC DDT context involvement."""
    comm, ids = fresh(world, loss=0.0)
    c = comm.registry.committed(ids["small"])
    assert c.msg_bytes < comm.cfg.eager_threshold
    mem = RNG.integers(0, 256, c.mem_bytes).astype(np.uint8)
    buf = np.zeros(c.mem_bytes, np.uint8)
    r = comm.irecv(0, buf, source=2, tag=9)
    s = comm.isend(2, 0, mem, tag=9, datatype=ids["small"])
    comm.wait(r, s)
    ref = ddtlib.unpack_np(c, ddtlib.pack_np(c, mem),
                           np.zeros(c.mem_bytes, np.uint8))
    np.testing.assert_array_equal(buf, ref)
    assert comm.engines[2].stats["eager_sent"] == 1


def test_concurrent_rendezvous_receives(world):
    """Several senders rendezvous into one receiver at once: slots must
    not cross-talk."""
    comm, ids = fresh(world, loss=0.05, seed=13)
    c = comm.registry.committed(ids["simple"])
    mems = {s: RNG.integers(0, 256, c.mem_bytes).astype(np.uint8)
            for s in (1, 2, 3)}
    bufs = {s: np.zeros(c.mem_bytes, np.uint8) for s in (1, 2, 3)}
    reqs = [comm.irecv(0, bufs[s], source=s, tag=4) for s in (1, 2, 3)]
    reqs += [comm.isend(s, 0, mems[s], tag=4, datatype=ids["simple"])
             for s in (1, 2, 3)]
    comm.wait(*reqs, max_ticks=100_000)
    for s in (1, 2, 3):
        ref = ddtlib.unpack_np(c, ddtlib.pack_np(c, mems[s]),
                               np.zeros(c.mem_bytes, np.uint8))
        np.testing.assert_array_equal(bufs[s], ref)


# ------------------------------------------------------------ collectives
def test_bcast_tree(world):
    comm, _ = fresh(world, loss=0.06, seed=5)
    root = 2
    data = RNG.normal(size=300).astype(np.float32)
    bufs = [data.copy() if r == root else np.zeros(300, np.float32)
            for r in range(N_RANKS)]
    mpi.bcast(comm, bufs, root=root)
    for r in range(N_RANKS):
        np.testing.assert_array_equal(bufs[r], data)


def test_reduce_sum_matches_numpy(world):
    comm, _ = fresh(world, loss=0.06, seed=6)
    vals = [RNG.normal(size=128).astype(np.float64)
            for _ in range(N_RANKS)]
    out = mpi.reduce(comm, vals, root=1, op=np.add)
    np.testing.assert_allclose(out, np.sum(vals, axis=0), rtol=1e-12)


def test_reduce_custom_op(world):
    comm, _ = fresh(world, loss=0.0)
    vals = [RNG.integers(0, 1000, 64).astype(np.int64)
            for _ in range(N_RANKS)]
    out = mpi.reduce(comm, vals, root=0, op=np.maximum)
    np.testing.assert_array_equal(out, np.max(vals, axis=0))


def test_allreduce_matches_numpy(world):
    comm, _ = fresh(world, loss=0.06, seed=7)
    vals = [RNG.normal(size=200).astype(np.float32)
            for _ in range(N_RANKS)]
    outs = mpi.allreduce(comm, vals, op=np.add)
    ref = np.sum(np.stack(vals).astype(np.float64), axis=0)
    for o in outs:
        np.testing.assert_allclose(o, ref, rtol=1e-4)


def test_alltoall_matches_numpy(world):
    comm, _ = fresh(world, loss=0.06, seed=8)
    mats = [RNG.integers(0, 1 << 30, (N_RANKS, 50)).astype(np.int64)
            for _ in range(N_RANKS)]
    recvs = mpi.alltoall(comm, mats)
    for r in range(N_RANKS):
        for i in range(N_RANKS):
            np.testing.assert_array_equal(recvs[r][i], mats[i][r])


def test_alltoallv_variable_and_zero_blocks(world):
    comm, _ = fresh(world, loss=0.05, seed=10)
    blocks = [[RNG.integers(0, 256, ((r + 3 * j) % 7) * 40).astype(np.uint8)
               for j in range(N_RANKS)] for r in range(N_RANKS)]
    recvs = mpi.alltoallv(comm, blocks)
    assert any(blocks[r][j].size == 0
               for r in range(N_RANKS) for j in range(N_RANKS))
    for r in range(N_RANKS):
        for i in range(N_RANKS):
            np.testing.assert_array_equal(recvs[r][i], blocks[i][r])


def test_barrier_completes(world):
    comm, _ = fresh(world, loss=0.05, seed=11)
    mpi.barrier(comm)
    assert all(e.done for e in comm.engines)
