"""Unit tests for the sPIN core: matching, allocator, HER/MPQ, DDT engine,
SLMP framing."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import alloc as palloc
from repro.core import ddt as ddtlib
from repro.core import her as herlib
from repro.core import matching as m
from repro.core import packet as pkt


# ------------------------------------------------------------- packets
def test_header_offsets_match_fig6():
    f = pkt.make_icmp_echo(np.arange(16, dtype=np.uint8))
    assert f[pkt.ETH_TYPE] == 0x08 and f[pkt.ETH_TYPE + 1] == 0x00
    assert f[pkt.IP_PROTO] == pkt.IPPROTO_ICMP
    assert f[pkt.ICMP_TYPE] == 8            # byte 34 == 8 (paper Fig 6)
    s = pkt.make_slmp(0xABCD, 0x1234, pkt.SLMP_FLAG_SYN,
                      np.zeros(4, np.uint8))
    assert int.from_bytes(bytes(s[pkt.SLMP_MSGID:pkt.SLMP_MSGID + 4]),
                          "big") == 0xABCD
    assert int.from_bytes(bytes(s[pkt.SLMP_OFFSET:pkt.SLMP_OFFSET + 4]),
                          "big") == 0x1234


def test_endian_helpers_roundtrip():
    d = jnp.zeros((64,), jnp.uint8)
    d = pkt.write_u32(d, 10, 0xDEADBEEF)
    assert int(pkt.read_u32(d, 10)) == 0xDEADBEEF
    d = pkt.write_u16(d, 2, 0xBEEF)
    assert int(pkt.read_u16(d, 2)) == 0xBEEF


def test_icmp_echo_rule_matches_listing2():
    """The paper's Listing-2 rule: word idx 8, mask 0xff00, start=end=0x0800."""
    r = m.RULE_ICMP_ECHO_REQ()
    assert r.idx == 8 and r.mask == 0xFF00
    assert r.start == 0x0800 and r.end == 0x0800


# ------------------------------------------------------------ allocator
def test_alloc_bimodal_classes():
    st = palloc.make_state()
    sizes = jnp.asarray([64, 128, 129, 1500], jnp.int32)
    valid = jnp.ones((4,), bool)
    st, addr, ok = palloc.alloc(st, sizes, valid)
    addr = np.asarray(addr)
    assert bool(ok.all())
    assert addr[0] < palloc.LARGE_BASE and addr[1] < palloc.LARGE_BASE
    assert addr[2] >= palloc.LARGE_BASE and addr[3] >= palloc.LARGE_BASE
    # distinct slots
    assert len(set(addr.tolist())) == 4


def test_alloc_exhaustion_and_free():
    st = palloc.make_state(n_small=4, n_large=2)
    sizes = jnp.full((8,), 64, jnp.int32)
    st, addr, ok = palloc.alloc(st, sizes, jnp.ones((8,), bool))
    assert int(ok.sum()) == 4                      # FIFO underflow -> drop
    st = palloc.free(st, addr, ok)
    st, addr2, ok2 = palloc.alloc(st, sizes, jnp.ones((8,), bool))
    assert int(ok2.sum()) == 4                     # slots recycled


def test_alloc_fifo_order():
    st = palloc.make_state(n_small=8, n_large=2)
    st, a1, _ = palloc.alloc(st, jnp.full((2,), 64, jnp.int32),
                             jnp.ones((2,), bool))
    st = palloc.free(st, a1, jnp.ones((2,), bool))
    st, a2, _ = palloc.alloc(st, jnp.full((6,), 64, jnp.int32),
                             jnp.ones((6,), bool))
    # pops continue round the FIFO before reusing freed slots
    assert set(np.asarray(a1).tolist()) & set(np.asarray(a2).tolist()[:4]) \
        == set()


# ------------------------------------------------------------- HER / MPQ
def test_her_header_tail_scheduling():
    mpq = herlib.make_mpq(16)
    n = 6
    ctx = jnp.zeros((n,), jnp.int32)
    addr = jnp.arange(n, dtype=jnp.int32) * 128
    size = jnp.full((n,), 100, jnp.int32)
    msg = jnp.asarray([1, 1, 1, 2, 2, 2], jnp.uint32)
    eom = jnp.asarray([False, False, True, False, False, True])
    valid = jnp.ones((n,), bool)
    mpq, her = herlib.generate(mpq, ctx, addr, size, msg, eom, valid)
    rh = np.asarray(her.run_header)
    rt = np.asarray(her.run_tail)
    assert rh.tolist() == [True, False, False, True, False, False]
    assert rt.tolist() == [False, False, True, False, False, True]
    # both messages completed -> MPQ empty again
    assert not bool(np.asarray(mpq.active).any())


def test_her_message_spanning_batches():
    mpq = herlib.make_mpq(16)
    one = lambda eom: (jnp.zeros((1,), jnp.int32),
                       jnp.zeros((1,), jnp.int32),
                       jnp.full((1,), 52, jnp.int32),
                       jnp.full((1,), 9, jnp.uint32),
                       jnp.asarray([eom]), jnp.ones((1,), bool))
    mpq, h1 = herlib.generate(mpq, *one(False))
    assert bool(h1.run_header[0])
    mpq, h2 = herlib.generate(mpq, *one(False))
    assert not bool(h2.run_header[0])       # already active: no header
    mpq, h3 = herlib.generate(mpq, *one(True))
    assert bool(h3.run_tail[0]) and not bool(h3.run_header[0])
    assert not bool(np.asarray(mpq.active).any())


# ---------------------------------------------------------------- DDT
def test_ddt_simple_segments():
    d = ddtlib.simple_ddt()       # vector: 8 blocks of 2 floats, stride 4
    segs = ddtlib.segments(d)
    assert len(segs) == 8
    assert segs[0] == (0, 8)       # 2 floats
    assert segs[1] == (16, 8)      # stride 4 floats = 16 bytes


def test_ddt_contiguous_merging():
    d = ddtlib.Contiguous(4, ddtlib.MPI_FLOAT)
    segs = ddtlib.segments(d)
    assert segs == [(0, 16)]       # dataloop contig-merge


def test_ddt_pack_unpack_numpy_roundtrip_simple():
    c = ddtlib.commit(ddtlib.simple_ddt(), count=2)
    rng = np.random.default_rng(0)
    mem = rng.integers(0, 256, c.mem_bytes).astype(np.uint8)
    msg = ddtlib.pack_np(c, mem)
    assert len(msg) == c.msg_bytes
    out = ddtlib.unpack_np(c, msg, np.zeros(c.mem_bytes, np.uint8))
    # all mapped bytes equal the source
    mask = c.mem_to_msg >= 0
    np.testing.assert_array_equal(out[mask], mem[mask])


def test_ddt_complex_has_overlap():
    d = ddtlib.complex_ddt()
    c = ddtlib.commit(d, count=1)
    # overlap: serialized size exceeds distinct memory bytes touched
    touched = (c.mem_to_msg >= 0).sum()
    assert c.msg_bytes > touched


def test_ddt_complex_unpack_last_wins():
    c = ddtlib.commit(ddtlib.complex_ddt(), count=1)
    msg = np.arange(c.msg_bytes, dtype=np.uint8)
    out = ddtlib.unpack_np(c, msg, np.zeros(c.mem_bytes, np.uint8))
    # for every memory byte, value must equal the LAST msg byte mapping it
    for b in range(c.mem_bytes):
        k = c.mem_to_msg[b]
        if k >= 0:
            assert out[b] == msg[k]


def test_element_maps_match_byte_maps():
    c = ddtlib.commit(ddtlib.simple_ddt(), count=4)
    pack_idx, unpack_idx = ddtlib.element_maps(c, 4)
    mem = np.random.default_rng(1).normal(
        size=c.mem_bytes // 4).astype(np.float32)
    msg_e = mem[pack_idx]
    msg_b = ddtlib.pack_np(c, mem.view(np.uint8))
    np.testing.assert_array_equal(msg_e.view(np.uint8), msg_b)


# ---------------------------------------------------------------- SLMP
def test_slmp_segmentation_flags():
    from repro.core import slmp
    cfg = slmp.SlmpSenderConfig(window=4, mtu_payload=100)
    frames = slmp.segment_message(np.zeros(950, np.uint8), 5, cfg)
    assert len(frames) == 10
    last = frames[-1]
    flags = int(pkt.read_u16(jnp.asarray(last), pkt.SLMP_FLAGS))
    assert flags & pkt.SLMP_FLAG_EOM
    first_flags = int(pkt.read_u16(jnp.asarray(frames[0]), pkt.SLMP_FLAGS))
    assert first_flags & pkt.SLMP_FLAG_SYN
